//! Hierarchical (multi-node) collective compiler.
//!
//! Lowers AllReduce / AllGather / ReduceScatter / Broadcast over a
//! [`Cluster`] to the canonical three-phase form:
//!
//! ```text
//!   phase 1: intra-node (NVLink/PCIe multipath)   — e.g. reduce-scatter
//!   phase 2: inter-node, striped across the node's RDMA NICs
//!   phase 3: intra-node (NVLink/PCIe multipath)   — e.g. all-gather
//! ```
//!
//! All three phases compile into ONE [`TaskGraph`] over the cluster's
//! shared [`ResourcePool`], so the existing fair-share DES prices
//! cross-tier contention (NIC uplinks and staged-PCIe traffic squeezing
//! the same lane, spine oversubscription, phase overlap through chunked
//! dependencies) with no additional machinery. Intra-phase tasks carry
//! their [`PathId`] tag, inter-phase tasks their [`StripeId`] tag — the
//! per-tier balancers each read their own completion times from one run.
//!
//! By default the phases are **chunk-pipelined** rather than joined with
//! whole-phase barriers: each inter-node stripe chunk starts the moment
//! the intra-phase chunks producing its bytes finish, and each phase-3
//! intra chunk starts the moment its stripe chunk lands (the dependency
//! threading runs through [`super::schedule::ChunkMap`]). The fair-share
//! DES then prices the resulting NVLink/PCIe/NIC overlap contention with
//! no additional machinery. The barriered lowering is kept behind
//! [`ClusterCollective::with_pipeline`] as the comparison baseline, and
//! single-chunk schedules compile to the barriered graph *task-for-task*
//! (chunk pipelining has nothing to thread there) — the degeneracy the
//! golden-trace and property suites pin.
//!
//! `n_nodes == 1` is the degenerate case: [`ClusterCollective::run`]
//! delegates to the flat single-node [`MultipathCollective`], so the
//! pre-cluster Table 2 numbers reproduce bit-identically.
//!
//! Intra-node phases carry a lowering-*algorithm* dimension
//! ([`ClusterCollective::with_algo`]): under `auto` each phase selects
//! ring / tree / halving-doubling from its **own** phase message size
//! (the [`super::algo`] analytic model), so a large collective whose
//! PCIe extent is small can still tree that extent. The inter-node ring
//! always stays ring. Non-ring phase-1 lowerings register their final
//! blocks in the same byte-interval producer maps, so chunk pipelining
//! into the inter phase survives the algorithm switch.
//!
//! Modeling note: when the inter tier's stripe shares deviate from the
//! even split, the surplus bytes are still charged to the carrier NIC
//! only — shuffling a shard to a neighbour GPU's NIC rides the NVSwitch
//! at ≥10× the NIC's single-put protocol rate, so that movement stays
//! below NIC-granularity model fidelity even though the NVLink fabric is
//! no longer idle between phases under the pipelined lowering.

use super::algo::{self, Algo, AlgoSpec};
use super::multipath::MultipathCollective;
use super::ring;
use super::schedule::{phase_span, ChunkMap, GraphBuilder};
use super::tree;
use super::CollectiveKind;
use crate::balancer::shares::Shares;
use crate::balancer::tier::TierShares;
use crate::links::calib::Calibration;
use crate::links::{PathId, PathModel, StripeId};
use crate::sim::{
    flow, Engine, ResourceId, ResourcePool, SimTime, TaskGraph, TaskId, TaskKind,
};
use crate::topology::cluster::Cluster;
use anyhow::Result;
use std::ops::Range;

/// Phase spans are the hoisted [`super::schedule::PhaseSpan`] — one
/// definition shared with the stream scheduler's per-op spans; re-exported
/// here because hierarchical reports are where they first appeared. The
/// per-tier balancers are unaffected by span overlap either way — they
/// read their tag-attributed completion times
/// ([`HierReport::intra_times`] / [`HierReport::inter_times`]), which
/// stay correct under it.
pub use super::schedule::PhaseSpan;

/// A bound (cluster, calibration, operator, local-rank-count) context —
/// the hierarchical analogue of [`MultipathCollective`].
pub struct ClusterCollective<'c> {
    pub cluster: &'c Cluster,
    pub calib: Calibration,
    pub kind: CollectiveKind,
    /// Ranks participating per node (the intra-node ring size); the
    /// cross-node phase stripes over this many NICs per node.
    pub n_local: usize,
    /// Chunk-level cross-phase pipelining (the default). `false` joins
    /// the phases with whole-phase barriers — kept as a first-class
    /// comparison baseline (`pipeline_phases` in `RunConfig`,
    /// `--no-pipeline` on the CLI, the overlap-gain column of
    /// `cluster_sweep`).
    pub pipeline: bool,
    /// Intra-phase lowering-algorithm policy. [`AlgoSpec::Auto`] picks
    /// per phase from the phase's *own* message size (a 256 MB AllReduce
    /// still runs small intra phases on its PCIe extent); fixed specs
    /// resolve per phase kind. The **inter** ring always stays ring —
    /// the NIC stripes are a bandwidth pipeline, not a latency problem.
    /// Defaults to ring ([`ClusterCollective::new`]) so direct
    /// constructions — golden traces, property suites, the paper-table
    /// benches — keep their pinned schedules; the Communicator wires the
    /// config's `algo` key (default auto) through
    /// [`ClusterCollective::with_algo`].
    pub algo: AlgoSpec,
    /// Pricing strategy for [`ClusterCollective::run`]: exact full-graph
    /// DES, symmetry-folded (when eligible), or size-adaptive. Defaults
    /// to [`PricingMode::Exact`] so every directly-constructed pinned
    /// schedule is untouched; the scale-aware harnesses and the stream
    /// scheduler's solo path opt into [`PricingMode::Auto`].
    pub pricing: PricingMode,
    /// Fair-share weight stamped on every physical-link flow of this
    /// collective (per-tenant QoS; defaults to `1.0` = legacy schedules
    /// bit-identical). Threaded into the per-node [`GraphBuilder`]s and
    /// the inter-phase stripe transfers; protocol/stripe resources are
    /// per-op private, so only *shared* lanes split by it.
    pub weight: f64,
    /// Node count at which [`PricingMode::Auto`] starts folding
    /// (default [`FOLD_AUTO_MIN_NODES`]; the `fold_min_nodes` run-config
    /// key / `--fold-min-nodes` CLI flag land here).
    pub fold_min_nodes: usize,
}

/// How [`ClusterCollective::run`] prices a multi-node collective.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PricingMode {
    /// Always compile + DES-run the full per-chunk cluster graph.
    #[default]
    Exact,
    /// Fold whenever [`ClusterCollective::fold_eligible`] holds; fall
    /// back to the exact graph otherwise (broken symmetry, unsupported
    /// operator).
    Folded,
    /// Fold only at [`FOLD_AUTO_MIN_NODES`]-node scale and above (and
    /// when eligible): small clusters keep the exact graph the golden
    /// suites pin, big sweeps get the sublinear representative pricing.
    Auto,
}

/// Node count at which [`PricingMode::Auto`] starts folding. Below this
/// the exact graph is cheap and stays the reference; at and above it the
/// folded graph is ~`n_nodes`× smaller per tier.
pub const FOLD_AUTO_MIN_NODES: usize = 16;

/// A compiled (not yet executed) hierarchical lowering: the task graph,
/// the resource pool it routes over, and the task-id watermarks of its
/// phases. Phases are emitted contiguously — phase 1 is `p1_range`,
/// the inter-node phase `p2_range`, phase 3 everything after — so a
/// phase *span* is an id-range query on the resulting schedule
/// ([`crate::sim::Schedule::range_span`]), which stays meaningful when
/// pipelined phases interleave in time.
#[derive(Debug, Clone)]
pub struct CompiledHier {
    pub pool: ResourcePool,
    pub graph: TaskGraph,
    /// Phase-1 (intra) task ids; empty for operators without a phase 1.
    pub p1_range: Range<usize>,
    /// Inter-node phase task ids.
    pub p2_range: Range<usize>,
    /// Phase-3 (intra) task ids — everything this lowering emitted after
    /// the inter phase. Recorded explicitly (not "to end of graph") so a
    /// plan compiled *onto* a shared stream-batch graph keeps its own
    /// watermark when later ops append more tasks.
    pub p3_range: Range<usize>,
}

/// DES outcome of one hierarchical collective.
#[derive(Debug, Clone)]
pub struct HierReport {
    pub kind: CollectiveKind,
    pub msg_bytes: u64,
    /// Makespan of the whole three-phase graph.
    pub total: SimTime,
    /// Per intra-node path completion (latest tagged task across nodes
    /// and phases) — the intra-tier balancer's observable.
    pub intra_times: Vec<(PathId, SimTime)>,
    /// Per NIC-stripe completion — the inter-tier balancer's observable.
    /// Empty in the degenerate single-node case.
    pub inter_times: Vec<(StripeId, SimTime)>,
    /// Span of phase 1 (EMPTY when the op has none, or at n = 1).
    pub intra_phase1: PhaseSpan,
    /// Span of the inter-node phase (EMPTY at n = 1). Under pipelining
    /// its `start` typically precedes `intra_phase1.end` — that overlap
    /// is the point.
    pub inter_phase: PhaseSpan,
    /// Span of phase 3 (EMPTY when the op has none, or at n = 1).
    pub intra_phase3: PhaseSpan,
    pub events: u64,
    pub tasks: usize,
    /// True when this pricing came from the symmetry-folded lowering
    /// (one representative rank group per tier, timings replicated
    /// analytically; `events`/`tasks` then count the *reduced* graph).
    /// Always `false` for exact runs and the single-node degenerate
    /// case. Fault-injected runs ([`ClusterCollective::run_under_faults`])
    /// fold only on an *empty* fault timeline — a mid-flight rate event
    /// is exactly a broken symmetry — while persistent NIC-leg
    /// degradation folds through the partial-symmetry classes
    /// ([`Cluster::fold_symmetry`]).
    pub folded: bool,
    /// Bytes routed over each *physical* resource, by name
    /// ([`crate::collectives::schedule::link_bytes`]) — the serve
    /// harness's fabric-utilization observable. Empty for folded
    /// pricings (the reduced graph's counters don't describe the full
    /// cluster) and fault-injected runs (failed tasks don't move their
    /// bytes); the serve path never folds (clusters below
    /// [`FOLD_AUTO_MIN_NODES`] price exact under `Auto`).
    pub link_bytes: Vec<(String, u64)>,
}

impl HierReport {
    /// Paper metric: algorithm bandwidth in GB/s.
    pub fn algbw_gbps(&self) -> f64 {
        self.kind.algbw_gbps(self.msg_bytes, self.total.as_secs_f64())
    }
}

/// Outcome of [`ClusterCollective::run_under_faults`]: the usual report
/// plus failure bookkeeping from the fault timeline.
#[derive(Debug, Clone)]
pub struct FaultedHierRun {
    pub report: HierReport,
    /// Tasks that failed (in-flight on a dead resource, or activated onto
    /// a dead route). 0 means the collective completed cleanly.
    pub failed_tasks: usize,
    /// Virtual time of the first failure, if any — the abort instant a
    /// recovery policy's detection latency counts from.
    pub first_failure: Option<SimTime>,
    /// Pool state at the end of the timeline (capacities after every
    /// applied event).
    pub pool: ResourcePool,
}

impl FaultedHierRun {
    /// True when the collective completed without failures — only then is
    /// `report.total` a valid step time.
    pub fn ok(&self) -> bool {
        self.failed_tasks == 0
    }
}

impl<'c> ClusterCollective<'c> {
    pub fn new(
        cluster: &'c Cluster,
        calib: Calibration,
        kind: CollectiveKind,
        n_local: usize,
    ) -> Self {
        assert!(
            n_local >= 2 && n_local <= cluster.gpus_per_node(),
            "n_local {} outside 2..={}",
            n_local,
            cluster.gpus_per_node()
        );
        ClusterCollective {
            cluster,
            calib,
            kind,
            n_local,
            pipeline: true,
            algo: AlgoSpec::Fixed(Algo::Ring),
            pricing: PricingMode::default(),
            weight: 1.0,
            fold_min_nodes: FOLD_AUTO_MIN_NODES,
        }
    }

    /// Select the phase-join strategy: `true` (default) threads per-chunk
    /// dependencies across phases, `false` rebuilds today's whole-phase
    /// barriers.
    pub fn with_pipeline(mut self, pipeline: bool) -> Self {
        self.pipeline = pipeline;
        self
    }

    /// Select the intra-phase algorithm policy (see the `algo` field).
    pub fn with_algo(mut self, algo: AlgoSpec) -> Self {
        self.algo = algo;
        self
    }

    /// Select the pricing strategy (see the `pricing` field).
    pub fn with_pricing(mut self, pricing: PricingMode) -> Self {
        self.pricing = pricing;
        self
    }

    /// Set the fair-share weight for every flow of this collective (see
    /// the `weight` field).
    pub fn with_weight(mut self, weight: f64) -> Self {
        self.weight = weight;
        self
    }

    /// Set the [`PricingMode::Auto`] fold threshold (see the
    /// `fold_min_nodes` field); clamped to ≥ 2 — folding needs at least
    /// two nodes to have anything to fold.
    pub fn with_fold_min_nodes(mut self, n: usize) -> Self {
        self.fold_min_nodes = n.max(2);
        self
    }

    /// Symmetry folding is sound when every node group prices
    /// identically *up to per-stripe NIC-leg degradation*: ≥ 2 nodes on
    /// one spine whose only capacity deviations are NIC up/down legs at
    /// or below nominal (see [`Cluster::fold_symmetry`] — the partial
    /// symmetry the fold prices by capping the affected stripe's rate),
    /// and a node-symmetric operator. Any other deviation (NVLink/PCIe
    /// lanes, above-nominal capacities) still prices exact. Broadcast is
    /// root-asymmetric (the root node runs phase 1, the others phase 3)
    /// and AllToAll has no hierarchical lowering, so both always price
    /// exact.
    pub fn fold_eligible(&self) -> bool {
        self.cluster.n_nodes() >= 2
            && matches!(
                self.kind,
                CollectiveKind::AllReduce
                    | CollectiveKind::AllGather
                    | CollectiveKind::ReduceScatter
            )
            && self.cluster.fold_symmetry().is_some()
    }

    fn should_fold(&self) -> bool {
        match self.pricing {
            PricingMode::Exact => false,
            PricingMode::Folded => self.fold_eligible(),
            PricingMode::Auto => {
                self.cluster.n_nodes() >= self.fold_min_nodes && self.fold_eligible()
            }
        }
    }

    /// Algorithm for one intra phase of `phase_kind` moving `msg` bytes
    /// on `path` — auto mode selects analytically from the phase's own
    /// message size (DES probes would recurse into the compiler);
    /// non-power-of-two local rings resolve to ring inside the registry.
    fn phase_algo(
        &self,
        phase_kind: CollectiveKind,
        path: PathId,
        msg: u64,
        models: &[(PathId, PathModel)],
    ) -> Algo {
        match self.algo {
            AlgoSpec::Fixed(a) => algo::resolve(phase_kind, a, self.n_local),
            AlgoSpec::Auto => {
                let model = models
                    .iter()
                    .find(|(p, _)| *p == path)
                    .map(|(_, m)| *m)
                    .expect("model for every active path");
                algo::select_analytic(
                    phase_kind,
                    self.n_local,
                    &model,
                    msg,
                    self.calib.reduce_bps,
                    path,
                )
            }
        }
    }

    /// Total participating ranks across the cluster.
    pub fn n_global(&self) -> usize {
        self.n_local * self.cluster.n_nodes()
    }

    /// Calibrated intra-node path model for a given phase collective.
    fn path_model(&self, phase_kind: CollectiveKind, path: PathId) -> PathModel {
        let spec = &self.cluster.spec.node;
        match path {
            PathId::Nvlink => {
                self.calib
                    .nvlink_model(phase_kind, self.n_local, spec.nvlink_unidir_bps())
            }
            PathId::Pcie => self.calib.pcie_model(spec.pcie_unidir_bps(), self.n_local),
            PathId::Rdma => self.calib.rdma_model(spec.nic_unidir_bps(), self.n_local),
        }
    }

    fn intra_models(
        &self,
        phase_kind: CollectiveKind,
        intra: &Shares<PathId>,
    ) -> Vec<(PathId, PathModel)> {
        intra
            .active_paths()
            .into_iter()
            .map(|p| (p, self.path_model(phase_kind, p)))
            .collect()
    }

    /// Total bytes the inter-node phase carries (before striping).
    fn inter_payload(&self, msg_bytes: u64) -> u64 {
        match self.kind {
            // Reduced shards: the whole vector crosses once per ring pass.
            CollectiveKind::AllReduce => msg_bytes,
            // Every local rank's contribution must reach every node.
            CollectiveKind::AllGather => msg_bytes * self.n_local as u64,
            CollectiveKind::ReduceScatter => msg_bytes,
            CollectiveKind::Broadcast => msg_bytes,
            CollectiveKind::AllToAll => msg_bytes,
        }
    }

    /// Compile + simulate one hierarchical collective under per-tier
    /// shares. `elem_bytes` aligns extent quantization (dtype size).
    pub fn run(
        &self,
        msg_bytes: u64,
        tiers: &TierShares,
        elem_bytes: u64,
    ) -> Result<HierReport> {
        anyhow::ensure!(msg_bytes > 0, "empty message");
        if self.cluster.n_nodes() == 1 {
            // Degenerate case: exactly the flat single-node pipeline.
            // Route the *cluster's* pool into the node view so failure
            // injection via `cluster.pool` (scale_capacity /
            // scale_matching) is honoured here too — at build time the
            // two pools are identical, so healthy timings stay
            // bit-identical to the flat path.
            let mut topo = self.cluster.node(0).clone();
            topo.pool = self.cluster.pool.clone();
            let mc = MultipathCollective::new(
                &topo,
                self.calib.clone(),
                self.kind,
                self.n_local,
            );
            let spec = mc
                .spec_algo(msg_bytes, &tiers.intra, elem_bytes, Algo::Ring)
                .with_weight(self.weight);
            let (outcome, link_bytes) =
                super::schedule::simulate_traced(&topo, &spec, self.calib.reduce_bps)?;
            let intra_times = outcome
                .per_path
                .iter()
                .filter(|p| p.bytes > 0)
                .map(|p| (p.path, p.time))
                .collect();
            return Ok(HierReport {
                kind: self.kind,
                msg_bytes,
                total: outcome.total,
                intra_times,
                inter_times: Vec::new(),
                intra_phase1: PhaseSpan::EMPTY,
                inter_phase: PhaseSpan::EMPTY,
                intra_phase3: PhaseSpan::EMPTY,
                events: outcome.events,
                tasks: outcome.tasks,
                folded: false,
                link_bytes,
            });
        }
        if self.should_fold() {
            // `None` = a live share routes over a dead NIC leg; price
            // that stripe (and therefore the op) exact.
            if let Some(rep) = self.run_folded(msg_bytes, tiers, elem_bytes)? {
                return Ok(rep);
            }
        }
        let compiled = self.compile(msg_bytes, tiers, elem_bytes)?;
        let tasks = compiled.graph.len();
        let link_bytes = super::schedule::link_bytes(&compiled.pool, &compiled.graph);
        let sched = Engine::new(&compiled.pool).run(&compiled.graph)?;
        let intra_times = tiers
            .intra
            .active_paths()
            .into_iter()
            .filter_map(|p| sched.tag_finish(&compiled.graph, p.tag()).map(|t| (p, t)))
            .collect();
        let inter_times = tiers
            .inter
            .active_paths()
            .into_iter()
            .filter_map(|s| sched.tag_finish(&compiled.graph, s.tag()).map(|t| (s, t)))
            .collect();
        Ok(HierReport {
            kind: self.kind,
            msg_bytes,
            total: sched.makespan,
            intra_times,
            inter_times,
            intra_phase1: phase_span(&sched, compiled.p1_range.clone()),
            inter_phase: phase_span(&sched, compiled.p2_range.clone()),
            intra_phase3: phase_span(&sched, compiled.p3_range.clone()),
            events: sched.events,
            tasks,
            folded: false,
            link_bytes,
        })
    }

    /// Symmetry-folded pricing: compile one representative rank group per
    /// tier — node 0's intra phases plus one node's view of each
    /// NIC-stripe inter ring, routed over [`Cluster::folded_pool`]'s
    /// spine share — DES-run the reduced graph once, and read every
    /// node's timings off it (identical copies price identically).
    /// Barriered, provably uncontended inter phases drop further to the
    /// closed-form flow evaluator ([`crate::sim::flow`]), embedded as
    /// per-stripe delays so spans/tags stay uniform. Callers reach this
    /// only through [`Self::run`] with [`Self::should_fold`] true.
    /// Returns `Ok(None)` when a stripe with a live share routes over a
    /// dead (zero-capacity) NIC leg — such a transfer never completes, so
    /// the caller must price exact (where the fault machinery fails the
    /// task instead of hanging).
    fn run_folded(
        &self,
        msg_bytes: u64,
        tiers: &TierShares,
        elem_bytes: u64,
    ) -> Result<Option<HierReport>> {
        debug_assert!(self.fold_eligible());
        let sym = self
            .cluster
            .fold_symmetry()
            .expect("fold_eligible gates on fold_symmetry");
        let payload = self.inter_payload(msg_bytes);
        let live_dead = tiers
            .inter
            .to_extents(payload, elem_bytes)
            .iter()
            .any(|(sid, _, len)| *len > 0 && sym.stripe_rates[sid.0 as usize] <= 0.0);
        if live_dead {
            return Ok(None);
        }
        let mut hg = HierGraph::folded(self);
        let (p1_range, p2_range) = match self.kind {
            CollectiveKind::AllReduce => {
                self.fold_allreduce(&mut hg, msg_bytes, tiers, elem_bytes)?
            }
            CollectiveKind::AllGather => {
                self.fold_allgather(&mut hg, msg_bytes, tiers, elem_bytes)?
            }
            CollectiveKind::ReduceScatter => {
                self.fold_reduce_scatter(&mut hg, msg_bytes, tiers, elem_bytes)?
            }
            _ => unreachable!("fold_eligible gates the operator set"),
        };
        let compiled = hg.into_compiled(p1_range, p2_range);
        let tasks = compiled.graph.len();
        let sched = Engine::new(&compiled.pool).run(&compiled.graph)?;
        let intra_times = tiers
            .intra
            .active_paths()
            .into_iter()
            .filter_map(|p| sched.tag_finish(&compiled.graph, p.tag()).map(|t| (p, t)))
            .collect();
        let inter_times = tiers
            .inter
            .active_paths()
            .into_iter()
            .filter_map(|s| sched.tag_finish(&compiled.graph, s.tag()).map(|t| (s, t)))
            .collect();
        Ok(Some(HierReport {
            kind: self.kind,
            msg_bytes,
            total: sched.makespan,
            intra_times,
            inter_times,
            intra_phase1: phase_span(&sched, compiled.p1_range.clone()),
            inter_phase: phase_span(&sched, compiled.p2_range.clone()),
            intra_phase3: phase_span(&sched, compiled.p3_range.clone()),
            events: sched.events,
            tasks,
            folded: true,
            link_bytes: Vec::new(),
        }))
    }

    /// As [`Self::run`], executed under a fault timeline
    /// ([`crate::sim::run_with_events`]): capacity mutations land
    /// mid-flight, in-flight transfers over dead resources fail, and the
    /// outcome carries failure bookkeeping beside the usual report.
    ///
    /// With an **empty timeline this is exactly [`Self::run`]'s code
    /// path** — including symmetry folding when [`Self::should_fold`]
    /// holds (the chaos loop's between-fault steps regain sublinear
    /// pricing this way; persistent NIC degradation folds through the
    /// partial-symmetry classes). Below the fold threshold
    /// `run_with_events` delegates to `Engine::run`, so a zero-fault
    /// chaos schedule stays bit-identical to the fault-free engine
    /// (pinned in `tests/prop_faults.rs` against the goldens). A
    /// *non-empty* timeline always prices exact: mid-flight rate events
    /// break the symmetry the fold depends on.
    ///
    /// On a failed run the report's timings are still well-defined (a
    /// failed task "finishes" at its failure instant) but do **not**
    /// price a completed collective — callers must check
    /// [`FaultedHierRun::ok`] before using `report.total` as a step time
    /// or feeding balancer observables.
    pub fn run_under_faults(
        &self,
        msg_bytes: u64,
        tiers: &TierShares,
        elem_bytes: u64,
        events: &[crate::sim::RateEvent],
    ) -> Result<FaultedHierRun> {
        anyhow::ensure!(
            self.cluster.n_nodes() >= 2,
            "fault-injected runs price multi-node clusters (n_nodes >= 2)"
        );
        if events.is_empty() && self.should_fold() {
            if let Some(report) = self.run_folded(msg_bytes, tiers, elem_bytes)? {
                return Ok(FaultedHierRun {
                    report,
                    failed_tasks: 0,
                    first_failure: None,
                    pool: self.cluster.pool.clone(),
                });
            }
        }
        let compiled = self.compile(msg_bytes, tiers, elem_bytes)?;
        let tasks = compiled.graph.len();
        let CompiledHier {
            pool,
            graph,
            p1_range,
            p2_range,
            p3_range,
        } = compiled;
        let run = crate::sim::run_with_events(pool, &graph, events)?;
        let sched = run.schedule;
        let intra_times = tiers
            .intra
            .active_paths()
            .into_iter()
            .filter_map(|p| sched.tag_finish(&graph, p.tag()).map(|t| (p, t)))
            .collect();
        let inter_times = tiers
            .inter
            .active_paths()
            .into_iter()
            .filter_map(|s| sched.tag_finish(&graph, s.tag()).map(|t| (s, t)))
            .collect();
        Ok(FaultedHierRun {
            report: HierReport {
                kind: self.kind,
                msg_bytes,
                total: sched.makespan,
                intra_times,
                inter_times,
                intra_phase1: phase_span(&sched, p1_range),
                inter_phase: phase_span(&sched, p2_range),
                intra_phase3: phase_span(&sched, p3_range),
                events: sched.events,
                tasks,
                folded: false,
                link_bytes: Vec::new(),
            },
            failed_tasks: run.failed.len(),
            first_failure: run.first_failure,
            pool: run.pool,
        })
    }

    /// Compile the multi-node lowering without executing it — the surface
    /// the structural tests (graph equality, per-resource byte
    /// conservation) inspect. `n_nodes == 1` has no hierarchical graph;
    /// use [`Self::run`], which delegates to the flat compiler there.
    pub fn compile(
        &self,
        msg_bytes: u64,
        tiers: &TierShares,
        elem_bytes: u64,
    ) -> Result<CompiledHier> {
        self.compile_onto(
            msg_bytes,
            tiers,
            elem_bytes,
            self.cluster.pool.clone(),
            TaskGraph::new(),
        )
    }

    /// As [`Self::compile`], appending onto an existing (pool, graph) —
    /// how the stream scheduler fuses several enqueued cluster
    /// collectives into ONE DES launch. The lowering adds its own
    /// protocol/stripe resources (its own streams into the NICs) while
    /// the raw physical links stay shared, so concurrent hierarchical
    /// collectives contend for the same lanes under max–min fair share.
    /// The returned phase ranges are absolute ids in the shared graph.
    pub fn compile_onto(
        &self,
        msg_bytes: u64,
        tiers: &TierShares,
        elem_bytes: u64,
        pool: ResourcePool,
        graph: TaskGraph,
    ) -> Result<CompiledHier> {
        anyhow::ensure!(msg_bytes > 0, "empty message");
        anyhow::ensure!(
            self.cluster.n_nodes() >= 2,
            "single-node collectives lower through MultipathCollective, not the \
             hierarchical compiler"
        );
        let hg = HierGraph::onto(self, pool, graph);
        match self.kind {
            CollectiveKind::AllReduce => {
                self.compile_allreduce(hg, msg_bytes, tiers, elem_bytes)
            }
            CollectiveKind::AllGather => {
                self.compile_allgather(hg, msg_bytes, tiers, elem_bytes)
            }
            CollectiveKind::ReduceScatter => {
                self.compile_reduce_scatter(hg, msg_bytes, tiers, elem_bytes)
            }
            CollectiveKind::Broadcast => {
                self.compile_broadcast(hg, msg_bytes, tiers, elem_bytes)
            }
            CollectiveKind::AllToAll => anyhow::bail!(
                "alltoall has no hierarchical lowering yet (single-node only)"
            ),
        }
    }

    /// Simulate the inter-node phase alone under candidate stripe shares
    /// — the stage-1 stripe tuner's measurable. Per-stripe completion
    /// times come back tagged exactly as in the full three-phase run.
    pub fn run_inter_only(
        &self,
        msg_bytes: u64,
        inter: &Shares<StripeId>,
    ) -> Result<Vec<(StripeId, SimTime)>> {
        anyhow::ensure!(
            self.cluster.n_nodes() >= 2,
            "inter phase needs ≥2 nodes"
        );
        let nn = self.cluster.n_nodes();
        let payload = self.inter_payload(msg_bytes);
        let ext = inter.to_extents(payload, crate::dtype::natural_align(payload));
        // A live share over a dead NIC leg can't fold (the stand-in
        // transfer would never finish) — probe it exact.
        let fold_ok = self.should_fold()
            && self.cluster.fold_symmetry().is_some_and(|sym| {
                !ext.iter()
                    .any(|(sid, _, len)| *len > 0 && sym.stripe_rates[sid.0 as usize] <= 0.0)
            });
        let mut hg;
        if fold_ok {
            // Folded stripe probing: the stripe tuner hammers this in a
            // loop at every scale, so the representative ring matters
            // most right here (tuning cost was the O(nodes²) term).
            hg = HierGraph::folded(self);
            let root = hg.barrier(Vec::new());
            for (sid, _, len) in &ext {
                let stripe = sid.0 as usize;
                let tag = sid.tag();
                match self.kind {
                    CollectiveKind::AllReduce => {
                        let finals = hg
                            .fold_ring_reduce_scatter(stripe, 0, *len, None, Some(root), tag);
                        let sub = len.div_ceil(nn as u64);
                        let mut at: Vec<Vec<TaskId>> =
                            finals.iter().map(|t| vec![*t]).collect();
                        for _s in 0..nn - 1 {
                            let arr = hg.send_inter(0, 0, stripe, sub, &at, false, tag);
                            at = arr.iter().map(|t| vec![*t]).collect();
                        }
                    }
                    CollectiveKind::AllGather => {
                        let n_chunks = hg.inter_chunks(*len);
                        let mut at: Vec<Vec<TaskId>> = vec![vec![root]; n_chunks];
                        for _s in 0..nn - 1 {
                            let arr = hg.send_inter(0, 0, stripe, *len, &at, false, tag);
                            at = arr.iter().map(|t| vec![*t]).collect();
                        }
                    }
                    CollectiveKind::ReduceScatter => {
                        hg.fold_ring_reduce_scatter(stripe, 0, *len, None, Some(root), tag);
                    }
                    _ => unreachable!("fold_eligible gates the operator set"),
                }
            }
        } else {
            hg = HierGraph::new(self);
            let root = hg.barrier(Vec::new());
            let entry = vec![root; nn];
            for (sid, _, len) in &ext {
                let stripe = sid.0 as usize;
                let tag = sid.tag();
                match self.kind {
                    CollectiveKind::AllReduce => {
                        let finals = hg.inter_ring_reduce_scatter(stripe, *len, &entry, tag);
                        let sub = len.div_ceil(nn as u64);
                        let start = chunked_deps(&finals);
                        hg.inter_ring_allgather(stripe, sub, &start, tag);
                    }
                    CollectiveKind::AllGather => {
                        let n_chunks = hg.inter_chunks(*len);
                        let start: Vec<Vec<Vec<TaskId>>> =
                            vec![vec![vec![root]; n_chunks]; nn];
                        hg.inter_ring_allgather(stripe, *len, &start, tag);
                    }
                    CollectiveKind::ReduceScatter => {
                        hg.inter_ring_reduce_scatter(stripe, *len, &entry, tag);
                    }
                    CollectiveKind::Broadcast => {
                        let entry = vec![vec![root]; hg.inter_chunks(*len)];
                        hg.inter_chain(stripe, *len, &entry, tag);
                    }
                    CollectiveKind::AllToAll => {
                        anyhow::bail!("alltoall has no hierarchical lowering yet")
                    }
                }
            }
        }
        let sched = Engine::new(&hg.pool).run(&hg.graph)?;
        Ok(ext
            .iter()
            .filter_map(|(sid, _, _)| {
                sched.tag_finish(&hg.graph, sid.tag()).map(|t| (*sid, t))
            })
            .collect())
    }

    // -----------------------------------------------------------------
    // Per-operator three-phase lowerings. Each compiles either the
    // chunk-pipelined graph (per-chunk dependency threading through
    // ChunkMaps) or the barriered graph (whole-phase joins); single-chunk
    // schedules always take the barriered shape — with one chunk per
    // block the pipeline has nothing to thread, so the two lowerings
    // must coincide task-for-task (pinned by tests/prop_pipeline.rs).
    // -----------------------------------------------------------------

    /// Phase 1 for the reducing operators: intra reduce-scatter on every
    /// node, per-path algorithm dispatched through `rs_algos` (parallel
    /// to `intra_ext`). Returns the per-node whole-phase barriers
    /// (barriered mode) or the per-node byte-interval producer maps over
    /// `[0, msg)` (pipelined mode; under ring, rank r's reduced block
    /// lands at offset `extent_off + rs_owned_block(r)·block`; under
    /// recursive halving at `extent_off + r·block` — the maps carry
    /// actual byte offsets, so the inter phase is ownership-agnostic).
    /// `n_emit` is the number of nodes to emit the phase for: the full
    /// `n_nodes` for exact graphs, 1 for the symmetry-folded
    /// representative (whose map/barrier then stands in for every node).
    fn phase1_reduce_scatter(
        &self,
        hg: &mut HierGraph<'_>,
        intra_ext: &[(PathId, u64, u64)],
        rs_models: &[(PathId, PathModel)],
        rs_algos: &[Algo],
        pipeline: bool,
        n_emit: usize,
    ) -> (Vec<TaskId>, Vec<ChunkMap>) {
        let nl = self.n_local as u64;
        let mut bars = Vec::new();
        let mut maps = Vec::new();
        for k in 0..n_emit {
            let mut map = ChunkMap::new();
            let mut finals_all: Vec<TaskId> = Vec::new();
            hg.with_node_builder(k, rs_models, |b| {
                for ((p, off, len), al) in intra_ext.iter().zip(rs_algos) {
                    let block = len.div_ceil(nl);
                    let (finals, owned_block): (Vec<Vec<TaskId>>, fn(usize, usize) -> usize) =
                        match al {
                            Algo::HalvingDoubling => (
                                algo::halving_reduce_scatter(b, *p, *len, &[], p.tag()),
                                |r, _n| r,
                            ),
                            _ => (
                                intra_ring_reduce_scatter(b, *p, block, &[], p.tag()),
                                ring::rs_owned_block,
                            ),
                        };
                    if pipeline {
                        let sizes = b.chunks_for(*p, block);
                        for (r, f) in finals.iter().enumerate() {
                            let blk = owned_block(r, nl as usize) as u64;
                            map.insert_chunks(*off + blk * block, &sizes, f);
                        }
                    } else {
                        for f in finals {
                            finals_all.extend(f);
                        }
                    }
                }
            });
            if pipeline {
                maps.push(map);
            } else {
                bars.push(hg.barrier(finals_all));
            }
        }
        (bars, maps)
    }

    /// AllReduce: intra reduce-scatter → inter ring allreduce per stripe
    /// → intra allgather.
    fn compile_allreduce(
        &self,
        mut hg: HierGraph<'_>,
        msg: u64,
        tiers: &TierShares,
        elem: u64,
    ) -> Result<CompiledHier> {
        let nn = self.cluster.n_nodes();
        let nl = self.n_local as u64;
        let base = hg.graph.len();
        let intra_ext = tiers.intra.to_extents(msg, elem);
        let inter_ext = tiers.inter.to_extents(msg, elem);
        let rs_models = self.intra_models(CollectiveKind::ReduceScatter, &tiers.intra);
        let ag_models = self.intra_models(CollectiveKind::AllGather, &tiers.intra);
        // Per-extent intra algorithms, selected from each phase's own
        // message size (phase 1 reduce-scatters `len`; phase 3 gathers
        // per-rank blocks of `len/nl`).
        let rs_algos: Vec<Algo> = intra_ext
            .iter()
            .map(|(p, _, len)| {
                self.phase_algo(CollectiveKind::ReduceScatter, *p, *len, &rs_models)
            })
            .collect();
        let ag_algos: Vec<Algo> = intra_ext
            .iter()
            .map(|(p, _, len)| {
                self.phase_algo(CollectiveKind::AllGather, *p, len.div_ceil(nl), &ag_models)
            })
            .collect();
        // Every PathModel this calibration emits shares `calib.chunk_bytes`
        // (intra paths and the inter NIC stripes alike).
        let chunk = self.calib.chunk_bytes;
        let pipeline = self.pipeline
            && !(intra_ext
                .iter()
                .all(|(_, _, len)| single_chunk(len.div_ceil(nl), chunk))
                && inter_ext
                    .iter()
                    .all(|(_, _, len)| single_chunk(len.div_ceil(nn as u64), chunk)));

        // Phase 1: intra reduce-scatter on every node.
        let (p1_bars, p1_maps) =
            self.phase1_reduce_scatter(&mut hg, &intra_ext, &rs_models, &rs_algos, pipeline, nn);
        let p1_end = hg.graph.len();

        // Phase 2: per-stripe inter-node ring allreduce of the shards.
        let mut done_per_node: Vec<Vec<TaskId>> = vec![Vec::new(); nn];
        let mut p2_maps: Vec<ChunkMap> = vec![ChunkMap::new(); nn];
        for (sid, s_off, len) in &inter_ext {
            let stripe = sid.0 as usize;
            let tag = sid.tag();
            let sub = len.div_ceil(nn as u64);
            if pipeline {
                let rs_finals =
                    hg.inter_ring_reduce_scatter_piped(stripe, *s_off, *len, &p1_maps, tag);
                let sub_sizes = ring::chunk_sizes(sub, hg.inter_model.chunk_bytes);
                for k in 0..nn {
                    // After the inter ring RS, node k owns the stripe's
                    // fully reduced sub-block (k+1) mod nn.
                    let own = ring::rs_owned_block(k, nn) as u64;
                    p2_maps[k].insert_chunks(*s_off + own * sub, &sub_sizes, &rs_finals[k]);
                }
                let start = chunked_deps(&rs_finals);
                let steps = hg.inter_ring_allgather_steps(stripe, sub, &start, tag);
                for (s, per_node) in steps.iter().enumerate() {
                    for m in 0..nn {
                        // AG step s delivers sub-block (m − s) mod nn to
                        // node m (see inter_ring_allgather_steps docs).
                        let blk = ((m + nn - s) % nn) as u64;
                        p2_maps[m].insert_chunks(
                            *s_off + blk * sub,
                            &sub_sizes,
                            &per_node[m],
                        );
                    }
                }
            } else {
                let rs_finals = hg.inter_ring_reduce_scatter(stripe, *len, &p1_bars, tag);
                let start = chunked_deps(&rs_finals);
                let ag_done = hg.inter_ring_allgather(stripe, sub, &start, tag);
                for k in 0..nn {
                    done_per_node[k].extend(rs_finals[k].iter().copied());
                    done_per_node[k].extend(ag_done[k].iter().copied());
                }
            }
        }
        let p2_bars: Vec<TaskId> = if pipeline {
            Vec::new()
        } else {
            done_per_node.into_iter().map(|d| hg.barrier(d)).collect()
        };
        let p2_end = hg.graph.len();

        // Phase 3: intra allgather of the fully reduced blocks; rank r
        // opens with block r of each extent (either algorithm starts
        // from the rank's own block, so the entry shape is shared).
        for k in 0..nn {
            hg.with_node_builder(k, &ag_models, |b| {
                for ((p, off, len), al) in intra_ext.iter().zip(&ag_algos) {
                    let block = len.div_ceil(nl);
                    let sizes = b.chunks_for(*p, block);
                    let entry: Vec<Vec<Vec<TaskId>>> = if pipeline {
                        (0..nl)
                            .map(|r| p2_maps[k].deps_for_chunks(*off + r * block, &sizes))
                            .collect()
                    } else {
                        vec![vec![vec![p2_bars[k]]; sizes.len()]; nl as usize]
                    };
                    intra_allgather_dispatch(b, *al, *p, block, &entry, p.tag());
                }
            });
        }
        Ok(hg.into_compiled(base..p1_end, p1_end..p2_end))
    }

    /// AllGather: inter ring allgather per stripe → intra allgather of
    /// the node-resident blocks (no reduce phase).
    fn compile_allgather(
        &self,
        mut hg: HierGraph<'_>,
        msg: u64,
        tiers: &TierShares,
        elem: u64,
    ) -> Result<CompiledHier> {
        let nn = self.cluster.n_nodes();
        let nl = self.n_local as u64;
        let base = hg.graph.len();
        let ag_models = self.intra_models(CollectiveKind::AllGather, &tiers.intra);
        let inter_ext = tiers.inter.to_extents(msg * nl, elem);
        let intra_ext = tiers.intra.to_extents(msg * nn as u64, elem);
        // Phase-3 algorithm per extent, from the per-rank gathered-group
        // size (each rank contributes `len` bytes to the intra ring).
        let ag_algos: Vec<Algo> = intra_ext
            .iter()
            .map(|(p, _, len)| self.phase_algo(CollectiveKind::AllGather, *p, *len, &ag_models))
            .collect();
        let chunk = self.calib.chunk_bytes;
        let pipeline = self.pipeline
            && !(inter_ext.iter().all(|(_, _, len)| single_chunk(*len, chunk))
                && intra_ext.iter().all(|(_, _, len)| single_chunk(*len, chunk)));

        // Phase 2 first: stripe g carries the g-th local rank's
        // contribution around the node ring. Inter coordinate space:
        // [0, msg·nl) = the node's local contributions concatenated in
        // rank order. Each node's availability map is *source-extended*
        // (src_node·stride + offset) so a phase-3 chunk can wait for one
        // specific node's copy of a slice rather than the slowest.
        let root = hg.barrier(Vec::new());
        let stride = msg * nl;
        let mut done_per_node: Vec<Vec<TaskId>> = vec![Vec::new(); nn];
        let mut p2_maps: Vec<ChunkMap> = vec![ChunkMap::new(); nn];
        for (sid, s_off, len) in &inter_ext {
            let stripe = sid.0 as usize;
            let sizes = ring::chunk_sizes(*len, hg.inter_model.chunk_bytes);
            let start: Vec<Vec<Vec<TaskId>>> = vec![vec![vec![root]; sizes.len()]; nn];
            let steps = hg.inter_ring_allgather_steps(stripe, *len, &start, sid.tag());
            for (s, per_node) in steps.iter().enumerate() {
                for m in 0..nn {
                    if pipeline {
                        // Step s delivers node (m − 1 − s) mod nn's copy
                        // to node m.
                        let src = (m + nn - 1 - s) % nn;
                        p2_maps[m].insert_chunks(
                            src as u64 * stride + *s_off,
                            &sizes,
                            &per_node[m],
                        );
                    } else {
                        done_per_node[m].extend(per_node[m].iter().copied());
                    }
                }
            }
        }
        let p2_bars: Vec<TaskId> = if pipeline {
            Vec::new()
        } else {
            done_per_node.into_iter().map(|d| hg.barrier(d)).collect()
        };
        let p2_end = hg.graph.len();

        // Phase 3: intra allgather; each rank forwards its gathered group
        // of `n_nodes` same-index copies (nn·msg bytes per rank before
        // the path split).
        for k in 0..nn {
            hg.with_node_builder(k, &ag_models, |b| {
                for ((p, off, len), al) in intra_ext.iter().zip(&ag_algos) {
                    let sizes = b.chunks_for(*p, *len);
                    let entry: Vec<Vec<Vec<TaskId>>> = if pipeline {
                        (0..self.n_local)
                            .map(|r| {
                                group_entry_deps(
                                    &p2_maps[k],
                                    k,
                                    r,
                                    *off,
                                    &sizes,
                                    msg,
                                    nn,
                                    stride,
                                )
                            })
                            .collect()
                    } else {
                        vec![vec![vec![p2_bars[k]]; sizes.len()]; self.n_local]
                    };
                    intra_allgather_dispatch(b, *al, *p, *len, &entry, p.tag());
                }
            });
        }
        Ok(hg.into_compiled(base..base, base..p2_end))
    }

    /// ReduceScatter: intra reduce-scatter → inter ring reduce-scatter
    /// per stripe (outputs land scattered; no phase 3).
    fn compile_reduce_scatter(
        &self,
        mut hg: HierGraph<'_>,
        msg: u64,
        tiers: &TierShares,
        elem: u64,
    ) -> Result<CompiledHier> {
        let nn = self.cluster.n_nodes();
        let nl = self.n_local as u64;
        let base = hg.graph.len();
        let intra_ext = tiers.intra.to_extents(msg, elem);
        let inter_ext = tiers.inter.to_extents(msg, elem);
        let rs_models = self.intra_models(CollectiveKind::ReduceScatter, &tiers.intra);
        let rs_algos: Vec<Algo> = intra_ext
            .iter()
            .map(|(p, _, len)| {
                self.phase_algo(CollectiveKind::ReduceScatter, *p, *len, &rs_models)
            })
            .collect();
        let chunk = self.calib.chunk_bytes;
        let pipeline = self.pipeline
            && !(intra_ext
                .iter()
                .all(|(_, _, len)| single_chunk(len.div_ceil(nl), chunk))
                && inter_ext
                    .iter()
                    .all(|(_, _, len)| single_chunk(len.div_ceil(nn as u64), chunk)));

        let (p1_bars, p1_maps) =
            self.phase1_reduce_scatter(&mut hg, &intra_ext, &rs_models, &rs_algos, pipeline, nn);
        let p1_end = hg.graph.len();

        for (sid, s_off, len) in &inter_ext {
            let stripe = sid.0 as usize;
            // The stripe extent IS the per-node slab (even stripes give
            // msg/n_local each); the node ring reduces it across nodes.
            if pipeline {
                hg.inter_ring_reduce_scatter_piped(stripe, *s_off, *len, &p1_maps, sid.tag());
            } else {
                hg.inter_ring_reduce_scatter(stripe, *len, &p1_bars, sid.tag());
            }
        }
        let p2_end = hg.graph.len();
        Ok(hg.into_compiled(base..p1_end, p1_end..p2_end))
    }

    /// Broadcast: intra chain at the root node → inter chain per stripe
    /// → intra allgather on the non-root nodes.
    fn compile_broadcast(
        &self,
        mut hg: HierGraph<'_>,
        msg: u64,
        tiers: &TierShares,
        elem: u64,
    ) -> Result<CompiledHier> {
        let nn = self.cluster.n_nodes();
        let nl = self.n_local as u64;
        let base = hg.graph.len();
        let intra_ext = tiers.intra.to_extents(msg, elem);
        let inter_ext = tiers.inter.to_extents(msg, elem);
        let bc_models = self.intra_models(CollectiveKind::Broadcast, &tiers.intra);
        let ag_models = self.intra_models(CollectiveKind::AllGather, &tiers.intra);
        // Phase-1 lowering per extent (pipelined chain vs binomial tree)
        // and phase-3 reassembly algorithm, each from its own phase size.
        let bc_algos: Vec<Algo> = intra_ext
            .iter()
            .map(|(p, _, len)| self.phase_algo(CollectiveKind::Broadcast, *p, *len, &bc_models))
            .collect();
        let ag_algos: Vec<Algo> = intra_ext
            .iter()
            .map(|(p, _, len)| {
                self.phase_algo(CollectiveKind::AllGather, *p, len.div_ceil(nl), &ag_models)
            })
            .collect();
        let chunk = self.calib.chunk_bytes;
        let pipeline = self.pipeline
            && !(intra_ext.iter().all(|(_, _, len)| single_chunk(*len, chunk))
                && inter_ext.iter().all(|(_, _, len)| single_chunk(*len, chunk)));

        // Phase 1: pipeline the message down the root node's local chain
        // so every local GPU (hence every NIC) holds a copy. Pipelined
        // mode keeps a per-rank producer map over [0, msg): stripe g's
        // uplink reads from GPU g, so it gates on *that rank's* arrivals.
        let mut at_rank: Vec<Vec<TaskId>> = vec![Vec::new(); self.n_local];
        let mut rank_maps: Vec<ChunkMap> = vec![ChunkMap::new(); self.n_local];
        hg.with_node_builder(0, &bc_models, |b| {
            for ((p, off, len), al) in intra_ext.iter().zip(&bc_algos) {
                let sizes = b.chunks_for(*p, *len);
                let arr = match al {
                    Algo::Tree => tree::build_broadcast(b, *p, *len, &[], p.tag()),
                    _ => intra_chain_broadcast(b, *p, *len, &[], p.tag()),
                };
                for (r, a) in arr.into_iter().enumerate() {
                    // Rank 0 is the source: locally resident, no map
                    // entries (its arrival list is empty).
                    if !a.is_empty() {
                        rank_maps[r].insert_chunks(*off, &sizes, &a);
                    }
                    at_rank[r].extend(a);
                }
            }
        });
        let p1_end = hg.graph.len();

        // Phase 2: stripe g forwards its slice down the node chain.
        let mut done_per_node: Vec<Vec<TaskId>> = vec![Vec::new(); nn];
        let mut p2_maps: Vec<ChunkMap> = vec![ChunkMap::new(); nn];
        for (sid, s_off, len) in &inter_ext {
            let stripe = sid.0 as usize;
            let sizes = ring::chunk_sizes(*len, hg.inter_model.chunk_bytes);
            let entry: Vec<Vec<TaskId>> = if pipeline {
                rank_maps[stripe].deps_for_chunks(*s_off, &sizes)
            } else {
                let bar = hg.barrier(at_rank[stripe].clone());
                vec![vec![bar]; sizes.len()]
            };
            let done = hg.inter_chain(stripe, *len, &entry, sid.tag());
            for k in 1..nn {
                if pipeline {
                    p2_maps[k].insert_chunks(*s_off, &sizes, &done[k]);
                }
                done_per_node[k].extend(done[k].iter().copied());
            }
        }
        let p2_bars: Vec<TaskId> = if pipeline {
            Vec::new()
        } else {
            done_per_node
                .iter()
                .skip(1)
                .map(|d| hg.barrier(d.clone()))
                .collect()
        };
        let p2_end = hg.graph.len();

        // Phase 3: non-root nodes reassemble the stripes locally.
        for k in 1..nn {
            hg.with_node_builder(k, &ag_models, |b| {
                for ((p, off, len), al) in intra_ext.iter().zip(&ag_algos) {
                    let block = len.div_ceil(nl);
                    let sizes = b.chunks_for(*p, block);
                    let entry: Vec<Vec<Vec<TaskId>>> = if pipeline {
                        (0..nl)
                            .map(|r| p2_maps[k].deps_for_chunks(*off + r * block, &sizes))
                            .collect()
                    } else {
                        vec![vec![vec![p2_bars[k - 1]]; sizes.len()]; self.n_local]
                    };
                    intra_allgather_dispatch(b, *al, *p, block, &entry, p.tag());
                }
            });
        }
        Ok(hg.into_compiled(base..p1_end, p1_end..p2_end))
    }

    // -----------------------------------------------------------------
    // Symmetry-folded lowerings: one representative rank group per tier.
    // Node 0 stands in for every node — its intra phases compile as
    // usual (its resource ids are a prefix of the shared pool, rebuilt
    // verbatim in the folded pool), and each inter ring compiles as node
    // 0's send chain with the real step count, routed over node 0's NIC
    // legs plus the scaled spine share. The key identity: under
    // symmetry, node k's step-(s−1) arrival from its ring predecessor
    // finishes exactly when node 0's own step-(s−1) send arrives, so
    // self-chaining the representative's steps (and standing node 0's
    // producer map in for its neighbours') reproduces the full graph's
    // timeline while emitting O(stripes·steps·chunks) tasks instead of
    // O(nodes·stripes·steps·chunks) — with the intra tier shrinking from
    // `n_nodes` node subgraphs to one.
    // -----------------------------------------------------------------

    /// Folded AllReduce: representative intra RS → per-stripe folded
    /// inter ring (RS + AG halves, or one closed-form flow delay when
    /// barriered and uncontended) → representative intra AG.
    fn fold_allreduce(
        &self,
        hg: &mut HierGraph<'_>,
        msg: u64,
        tiers: &TierShares,
        elem: u64,
    ) -> Result<(Range<usize>, Range<usize>)> {
        let nn = self.cluster.n_nodes();
        let nl = self.n_local as u64;
        let base = hg.graph.len();
        let intra_ext = tiers.intra.to_extents(msg, elem);
        let inter_ext = tiers.inter.to_extents(msg, elem);
        let rs_models = self.intra_models(CollectiveKind::ReduceScatter, &tiers.intra);
        let ag_models = self.intra_models(CollectiveKind::AllGather, &tiers.intra);
        let rs_algos: Vec<Algo> = intra_ext
            .iter()
            .map(|(p, _, len)| {
                self.phase_algo(CollectiveKind::ReduceScatter, *p, *len, &rs_models)
            })
            .collect();
        let ag_algos: Vec<Algo> = intra_ext
            .iter()
            .map(|(p, _, len)| {
                self.phase_algo(CollectiveKind::AllGather, *p, len.div_ceil(nl), &ag_models)
            })
            .collect();
        let chunk = self.calib.chunk_bytes;
        let pipeline = self.pipeline
            && !(intra_ext
                .iter()
                .all(|(_, _, len)| single_chunk(len.div_ceil(nl), chunk))
                && inter_ext
                    .iter()
                    .all(|(_, _, len)| single_chunk(len.div_ceil(nn as u64), chunk)));

        if pipeline
            && hg.fold_flow_eligible(&inter_ext)
            && flow_intra_ok(&intra_ext, rs_algos.iter().chain(&ag_algos))
        {
            // Pipelined-fold fast path: the whole three-phase chunk
            // pipeline has a closed form (intra-RS chain → staged inter
            // RS+AG chains → intra-AG ring), so no task graph at all.
            return Ok(self.fold_flow_allreduce(
                hg, &intra_ext, &inter_ext, &rs_models, &ag_models, base,
            ));
        }

        let (p1_bars, p1_maps) =
            self.phase1_reduce_scatter(hg, &intra_ext, &rs_models, &rs_algos, pipeline, 1);
        let p1_end = hg.graph.len();

        let flow_ok = !pipeline && hg.fold_flow_eligible(&inter_ext);
        let mut p2_done: Vec<TaskId> = Vec::new();
        let mut p2_map = ChunkMap::new();
        for (sid, s_off, len) in &inter_ext {
            let stripe = sid.0 as usize;
            let tag = sid.tag();
            let sub = len.div_ceil(nn as u64);
            let sub_sizes = ring::chunk_sizes(sub, hg.inter_model.chunk_bytes);
            if flow_ok {
                // Closed-form: chunk-wavefront RS chain feeding the AG
                // chain, at the stripe's private bottleneck rate — the
                // AG half starts on the egress the RS half vacated.
                let (rs, eg) =
                    hg.fold_flow_phase(stripe, sub, nn - 1, true, &[], SimTime::ZERO);
                let (ag, _) = hg.fold_flow_phase(stripe, sub, nn - 1, false, &rs, eg);
                let dur = ag.into_iter().fold(SimTime::ZERO, SimTime::max);
                let d = hg.graph.add_tagged(
                    TaskKind::Delay { duration: dur },
                    vec![p1_bars[0]],
                    tag,
                );
                p2_done.push(d);
                continue;
            }
            if pipeline {
                let finals = hg.fold_ring_reduce_scatter(
                    stripe,
                    *s_off,
                    *len,
                    Some(&p1_maps[0]),
                    None,
                    tag,
                );
                let own = ring::rs_owned_block(0, nn) as u64;
                p2_map.insert_chunks(*s_off + own * sub, &sub_sizes, &finals);
                let mut at: Vec<Vec<TaskId>> =
                    finals.iter().map(|t| vec![*t]).collect();
                for s in 0..nn - 1 {
                    let arr = hg.send_inter(0, 0, stripe, sub, &at, false, tag);
                    // AG step s delivers sub-block (nn − s) mod nn to the
                    // representative (the m = 0 case of the exact graph's
                    // attribution).
                    let blk = ((nn - s) % nn) as u64;
                    p2_map.insert_chunks(*s_off + blk * sub, &sub_sizes, &arr);
                    at = arr.iter().map(|t| vec![*t]).collect();
                }
            } else {
                let finals = hg.fold_ring_reduce_scatter(
                    stripe,
                    *s_off,
                    *len,
                    None,
                    Some(p1_bars[0]),
                    tag,
                );
                p2_done.extend(finals.iter().copied());
                let mut at: Vec<Vec<TaskId>> =
                    finals.iter().map(|t| vec![*t]).collect();
                for _s in 0..nn - 1 {
                    let arr = hg.send_inter(0, 0, stripe, sub, &at, false, tag);
                    p2_done.extend(arr.iter().copied());
                    at = arr.iter().map(|t| vec![*t]).collect();
                }
            }
        }
        let p2_bar = if pipeline {
            None
        } else {
            Some(hg.barrier(p2_done))
        };
        let p2_end = hg.graph.len();

        hg.with_node_builder(0, &ag_models, |b| {
            for ((p, off, len), al) in intra_ext.iter().zip(&ag_algos) {
                let block = len.div_ceil(nl);
                let sizes = b.chunks_for(*p, block);
                let entry: Vec<Vec<Vec<TaskId>>> = if pipeline {
                    (0..nl)
                        .map(|r| p2_map.deps_for_chunks(*off + r * block, &sizes))
                        .collect()
                } else {
                    vec![vec![vec![p2_bar.unwrap()]; sizes.len()]; nl as usize]
                };
                intra_allgather_dispatch(b, *al, *p, block, &entry, p.tag());
            }
        });
        Ok((base..p1_end, p1_end..p2_end))
    }

    /// Folded AllGather: per-stripe folded inter ring (or flow delay) →
    /// representative intra AG over the source-extended arrival map.
    fn fold_allgather(
        &self,
        hg: &mut HierGraph<'_>,
        msg: u64,
        tiers: &TierShares,
        elem: u64,
    ) -> Result<(Range<usize>, Range<usize>)> {
        let nn = self.cluster.n_nodes();
        let nl = self.n_local as u64;
        let base = hg.graph.len();
        let ag_models = self.intra_models(CollectiveKind::AllGather, &tiers.intra);
        let inter_ext = tiers.inter.to_extents(msg * nl, elem);
        let intra_ext = tiers.intra.to_extents(msg * nn as u64, elem);
        let ag_algos: Vec<Algo> = intra_ext
            .iter()
            .map(|(p, _, len)| self.phase_algo(CollectiveKind::AllGather, *p, *len, &ag_models))
            .collect();
        let chunk = self.calib.chunk_bytes;
        let pipeline = self.pipeline
            && !(inter_ext.iter().all(|(_, _, len)| single_chunk(*len, chunk))
                && intra_ext.iter().all(|(_, _, len)| single_chunk(*len, chunk)));

        let stride = msg * nl;
        if pipeline
            && hg.fold_flow_eligible(&inter_ext)
            && flow_intra_ok(&intra_ext, ag_algos.iter())
        {
            return Ok(self.fold_flow_allgather(
                hg, &intra_ext, &inter_ext, &ag_models, msg, stride, base,
            ));
        }

        let root = hg.barrier(Vec::new());
        let flow_ok = !pipeline && hg.fold_flow_eligible(&inter_ext);
        let mut p2_done: Vec<TaskId> = Vec::new();
        let mut p2_map = ChunkMap::new();
        for (sid, s_off, len) in &inter_ext {
            let stripe = sid.0 as usize;
            let tag = sid.tag();
            let sizes = ring::chunk_sizes(*len, hg.inter_model.chunk_bytes);
            if flow_ok {
                let (arr, _) =
                    hg.fold_flow_phase(stripe, *len, nn - 1, false, &[], SimTime::ZERO);
                let dur = arr.into_iter().fold(SimTime::ZERO, SimTime::max);
                let d = hg.graph.add_tagged(
                    TaskKind::Delay { duration: dur },
                    vec![root],
                    tag,
                );
                p2_done.push(d);
                continue;
            }
            let mut at: Vec<Vec<TaskId>> = vec![vec![root]; sizes.len()];
            for s in 0..nn - 1 {
                let arr = hg.send_inter(0, 0, stripe, *len, &at, false, tag);
                if pipeline {
                    // Step s delivers node (nn − 1 − s)'s copy to the
                    // representative (the m = 0 case).
                    let src = (nn - 1 - s) % nn;
                    p2_map.insert_chunks(src as u64 * stride + *s_off, &sizes, &arr);
                } else {
                    p2_done.extend(arr.iter().copied());
                }
                at = arr.iter().map(|t| vec![*t]).collect();
            }
        }
        let p2_bar = if pipeline {
            None
        } else {
            Some(hg.barrier(p2_done))
        };
        let p2_end = hg.graph.len();

        hg.with_node_builder(0, &ag_models, |b| {
            for ((p, off, len), al) in intra_ext.iter().zip(&ag_algos) {
                let sizes = b.chunks_for(*p, *len);
                let entry: Vec<Vec<Vec<TaskId>>> = if pipeline {
                    (0..self.n_local)
                        .map(|r| {
                            group_entry_deps(&p2_map, 0, r, *off, &sizes, msg, nn, stride)
                        })
                        .collect()
                } else {
                    vec![vec![vec![p2_bar.unwrap()]; sizes.len()]; self.n_local]
                };
                intra_allgather_dispatch(b, *al, *p, *len, &entry, p.tag());
            }
        });
        Ok((base..base, base..p2_end))
    }

    /// Folded ReduceScatter: representative intra RS → per-stripe folded
    /// inter RS chain (or flow delay); outputs land scattered, no phase 3.
    fn fold_reduce_scatter(
        &self,
        hg: &mut HierGraph<'_>,
        msg: u64,
        tiers: &TierShares,
        elem: u64,
    ) -> Result<(Range<usize>, Range<usize>)> {
        let nn = self.cluster.n_nodes();
        let nl = self.n_local as u64;
        let base = hg.graph.len();
        let intra_ext = tiers.intra.to_extents(msg, elem);
        let inter_ext = tiers.inter.to_extents(msg, elem);
        let rs_models = self.intra_models(CollectiveKind::ReduceScatter, &tiers.intra);
        let rs_algos: Vec<Algo> = intra_ext
            .iter()
            .map(|(p, _, len)| {
                self.phase_algo(CollectiveKind::ReduceScatter, *p, *len, &rs_models)
            })
            .collect();
        let chunk = self.calib.chunk_bytes;
        let pipeline = self.pipeline
            && !(intra_ext
                .iter()
                .all(|(_, _, len)| single_chunk(len.div_ceil(nl), chunk))
                && inter_ext
                    .iter()
                    .all(|(_, _, len)| single_chunk(len.div_ceil(nn as u64), chunk)));

        if pipeline
            && hg.fold_flow_eligible(&inter_ext)
            && flow_intra_ok(&intra_ext, rs_algos.iter())
        {
            return Ok(self.fold_flow_reduce_scatter(
                hg, &intra_ext, &inter_ext, &rs_models, base,
            ));
        }

        let (p1_bars, p1_maps) =
            self.phase1_reduce_scatter(hg, &intra_ext, &rs_models, &rs_algos, pipeline, 1);
        let p1_end = hg.graph.len();

        let flow_ok = !pipeline && hg.fold_flow_eligible(&inter_ext);
        for (sid, s_off, len) in &inter_ext {
            let stripe = sid.0 as usize;
            let tag = sid.tag();
            if flow_ok {
                let sub = len.div_ceil(nn as u64);
                let (arr, _) =
                    hg.fold_flow_phase(stripe, sub, nn - 1, true, &[], SimTime::ZERO);
                let dur = arr.into_iter().fold(SimTime::ZERO, SimTime::max);
                hg.graph.add_tagged(
                    TaskKind::Delay { duration: dur },
                    vec![p1_bars[0]],
                    tag,
                );
            } else if pipeline {
                hg.fold_ring_reduce_scatter(
                    stripe,
                    *s_off,
                    *len,
                    Some(&p1_maps[0]),
                    None,
                    tag,
                );
            } else {
                hg.fold_ring_reduce_scatter(
                    stripe,
                    *s_off,
                    *len,
                    None,
                    Some(p1_bars[0]),
                    tag,
                );
            }
        }
        let p2_end = hg.graph.len();
        Ok((base..p1_end, p1_end..p2_end))
    }

    // -----------------------------------------------------------------
    // Pipelined-fold flow path: when every intra phase is an NVLink ring
    // and every stripe is uncontended, the whole chunk-pipelined
    // three-phase graph has a closed form — per-phase FIFO chunk chains
    // coupled through TimeMaps (the flow evaluator's ChunkMap). The
    // graph shrinks to one tagged Delay per path extent / stripe, priced
    // by absolute duration; O(paths + stripes) tasks independent of both
    // node count AND chunk count.
    // -----------------------------------------------------------------

    /// Bottleneck rate of one representative NVLink ring hop: the
    /// per-stream protocol cap ([`GraphBuilder`] proto resources carry
    /// `model.rate_cap`) against node 0's lane capacities — uncontended,
    /// since each ring rank sends on its own up-lane into its
    /// successor's private down-lane.
    fn fold_intra_chain_rate(&self, hg: &HierGraph<'_>, model: &PathModel) -> f64 {
        let node0 = self.cluster.node(0);
        flow::bottleneck_rate(
            [
                hg.pool.capacity(node0.nvlink_up[0]),
                hg.pool.capacity(node0.nvlink_down[0]),
            ],
            model.rate_cap,
        )
    }

    /// Closed-form phase 1 (representative intra ring reduce-scatter):
    /// one FIFO chunk chain of `n_local − 1` hops per path extent,
    /// emitted as a single tagged Delay. Returns the byte-range arrival
    /// map of the *reduced* blocks — by symmetry every rank's chain is
    /// identical, so rank r's owned block (at
    /// `off + rs_owned_block(r)·block`) carries the same per-chunk
    /// times. NVLink pays its combine inside the fitted B_eff: the
    /// reduce cost rides the per-step gate, never a per-arrival delay
    /// ([`GraphBuilder::send_block`]'s Nvlink arm).
    fn fold_flow_phase1(
        &self,
        hg: &mut HierGraph<'_>,
        intra_ext: &[(PathId, u64, u64)],
        rs_models: &[(PathId, PathModel)],
    ) -> flow::TimeMap {
        let nl = self.n_local as u64;
        let mut t1 = flow::TimeMap::new();
        for (p, off, len) in intra_ext {
            let model = model_for(rs_models, *p);
            let block = len.div_ceil(nl);
            let sizes = ring::chunk_sizes(block, model.chunk_bytes);
            let spec = flow::ChainSpec {
                steps: self.n_local - 1,
                gate: model.step_latency + model.reduce_step_latency,
                rate_bps: self.fold_intra_chain_rate(hg, &model),
                reduce_bps: None,
            };
            let arrivals =
                flow::chain_arrivals(&spec, &sizes, &vec![SimTime::ZERO; sizes.len()]);
            for r in 0..self.n_local {
                let blk = ring::rs_owned_block(r, self.n_local) as u64;
                t1.insert_chunks(*off + blk * block, &sizes, &arrivals);
            }
            let fin = arrivals.into_iter().fold(SimTime::ZERO, SimTime::max);
            hg.graph
                .add_tagged(TaskKind::Delay { duration: fin }, vec![], p.tag());
        }
        t1
    }

    /// Pipelined-fold AllReduce: phase-1 chain → per stripe a staged
    /// inter RS chain (ring step s's block becomes ready as phase 1
    /// produces it) feeding the AG chain on the same egress → intra AG
    /// ring with per-rank entry times.
    fn fold_flow_allreduce(
        &self,
        hg: &mut HierGraph<'_>,
        intra_ext: &[(PathId, u64, u64)],
        inter_ext: &[(StripeId, u64, u64)],
        rs_models: &[(PathId, PathModel)],
        ag_models: &[(PathId, PathModel)],
        base: usize,
    ) -> (Range<usize>, Range<usize>) {
        let nn = self.cluster.n_nodes();
        let nl = self.n_local as u64;
        let t1 = self.fold_flow_phase1(hg, intra_ext, rs_models);
        let p1_end = hg.graph.len();

        let mut t2 = flow::TimeMap::new();
        for (sid, s_off, len) in inter_ext {
            let stripe = sid.0 as usize;
            let tag = sid.tag();
            if *len == 0 {
                hg.graph
                    .add_tagged(TaskKind::Delay { duration: SimTime::ZERO }, vec![], tag);
                continue;
            }
            let sub = len.div_ceil(nn as u64);
            let sizes = ring::chunk_sizes(sub, hg.inter_model.chunk_bytes);
            let ext: Vec<Vec<SimTime>> = (0..nn - 1)
                .map(|s| {
                    let blk = ring::rs_send_block(0, s, nn) as u64;
                    t1.ready_for_chunks(*s_off + blk * sub, &sizes)
                })
                .collect();
            let rs_spec = hg.fold_chain_spec(stripe, nn - 1, true);
            let (rs_steps, eg) =
                flow::staged_chain_steps_from(&rs_spec, &sizes, &ext, SimTime::ZERO);
            let finals = rs_steps.into_iter().next_back().expect("nn >= 2");
            let own = ring::rs_owned_block(0, nn) as u64;
            t2.insert_chunks(*s_off + own * sub, &sizes, &finals);
            let mut fin = finals.iter().copied().fold(SimTime::ZERO, SimTime::max);
            // The AG half reuses the wire the RS half just vacated.
            let ag_spec = hg.fold_chain_spec(stripe, nn - 1, false);
            let (ag_steps, _) = flow::chain_steps_from(&ag_spec, &sizes, &finals, eg);
            for (s, arr) in ag_steps.iter().enumerate() {
                // AG step s delivers sub-block (nn − s) mod nn to the
                // representative (the m = 0 case of the exact graph's
                // attribution).
                let blk = ((nn - s) % nn) as u64;
                t2.insert_chunks(*s_off + blk * sub, &sizes, arr);
                fin = arr.iter().copied().fold(fin, SimTime::max);
            }
            hg.graph
                .add_tagged(TaskKind::Delay { duration: fin }, vec![], tag);
        }
        let p2_end = hg.graph.len();

        for (p, off, len) in intra_ext {
            let model = model_for(ag_models, *p);
            let block = len.div_ceil(nl);
            let sizes = ring::chunk_sizes(block, model.chunk_bytes);
            let entry: Vec<Vec<SimTime>> = (0..nl)
                .map(|r| t2.ready_for_chunks(*off + r * block, &sizes))
                .collect();
            let spec = flow::ChainSpec {
                steps: 1, // ignored: the ring evaluator runs n_local − 1
                gate: model.step_latency,
                rate_bps: self.fold_intra_chain_rate(hg, &model),
                reduce_bps: None,
            };
            let done = flow::ring_allgather_times(&spec, &sizes, &entry);
            let fin = done.into_iter().fold(SimTime::ZERO, SimTime::max);
            hg.graph
                .add_tagged(TaskKind::Delay { duration: fin }, vec![], p.tag());
        }
        (base..p1_end, p1_end..p2_end)
    }

    /// Pipelined-fold AllGather: per stripe a plain inter chain whose
    /// step-s arrivals land at source node (nn − 1 − s)'s group slot →
    /// intra AG ring over per-rank gathered-group entry times.
    #[allow(clippy::too_many_arguments)]
    fn fold_flow_allgather(
        &self,
        hg: &mut HierGraph<'_>,
        intra_ext: &[(PathId, u64, u64)],
        inter_ext: &[(StripeId, u64, u64)],
        ag_models: &[(PathId, PathModel)],
        msg: u64,
        stride: u64,
        base: usize,
    ) -> (Range<usize>, Range<usize>) {
        let nn = self.cluster.n_nodes();
        let mut t2 = flow::TimeMap::new();
        for (sid, s_off, len) in inter_ext {
            let stripe = sid.0 as usize;
            let tag = sid.tag();
            if *len == 0 {
                hg.graph
                    .add_tagged(TaskKind::Delay { duration: SimTime::ZERO }, vec![], tag);
                continue;
            }
            let sizes = ring::chunk_sizes(*len, hg.inter_model.chunk_bytes);
            let spec = hg.fold_chain_spec(stripe, nn - 1, false);
            let steps =
                flow::chain_steps(&spec, &sizes, &vec![SimTime::ZERO; sizes.len()]);
            let mut fin = SimTime::ZERO;
            for (s, arr) in steps.iter().enumerate() {
                // Step s delivers node (nn − 1 − s)'s copy to the
                // representative (the m = 0 case).
                let src = ((nn - 1 - s) % nn) as u64;
                t2.insert_chunks(src * stride + *s_off, &sizes, arr);
                fin = arr.iter().copied().fold(fin, SimTime::max);
            }
            hg.graph
                .add_tagged(TaskKind::Delay { duration: fin }, vec![], tag);
        }
        let p2_end = hg.graph.len();

        for (p, off, len) in intra_ext {
            let model = model_for(ag_models, *p);
            let sizes = ring::chunk_sizes(*len, model.chunk_bytes);
            let entry: Vec<Vec<SimTime>> = (0..self.n_local)
                .map(|r| group_entry_times(&t2, r, *off, &sizes, msg, nn, stride))
                .collect();
            let spec = flow::ChainSpec {
                steps: 1, // ignored: the ring evaluator runs n_local − 1
                gate: model.step_latency,
                rate_bps: self.fold_intra_chain_rate(hg, &model),
                reduce_bps: None,
            };
            let done = flow::ring_allgather_times(&spec, &sizes, &entry);
            let fin = done.into_iter().fold(SimTime::ZERO, SimTime::max);
            hg.graph
                .add_tagged(TaskKind::Delay { duration: fin }, vec![], p.tag());
        }
        (base..base, base..p2_end)
    }

    /// Pipelined-fold ReduceScatter: phase-1 chain → per stripe a staged
    /// inter RS chain; outputs land scattered, no phase 3.
    fn fold_flow_reduce_scatter(
        &self,
        hg: &mut HierGraph<'_>,
        intra_ext: &[(PathId, u64, u64)],
        inter_ext: &[(StripeId, u64, u64)],
        rs_models: &[(PathId, PathModel)],
        base: usize,
    ) -> (Range<usize>, Range<usize>) {
        let nn = self.cluster.n_nodes();
        let t1 = self.fold_flow_phase1(hg, intra_ext, rs_models);
        let p1_end = hg.graph.len();
        for (sid, s_off, len) in inter_ext {
            let stripe = sid.0 as usize;
            let tag = sid.tag();
            if *len == 0 {
                hg.graph
                    .add_tagged(TaskKind::Delay { duration: SimTime::ZERO }, vec![], tag);
                continue;
            }
            let sub = len.div_ceil(nn as u64);
            let sizes = ring::chunk_sizes(sub, hg.inter_model.chunk_bytes);
            let ext: Vec<Vec<SimTime>> = (0..nn - 1)
                .map(|s| {
                    let blk = ring::rs_send_block(0, s, nn) as u64;
                    t1.ready_for_chunks(*s_off + blk * sub, &sizes)
                })
                .collect();
            let spec = hg.fold_chain_spec(stripe, nn - 1, true);
            let finals = flow::staged_chain_steps(&spec, &sizes, &ext)
                .into_iter()
                .next_back()
                .expect("nn >= 2");
            let fin = finals.into_iter().fold(SimTime::ZERO, SimTime::max);
            hg.graph
                .add_tagged(TaskKind::Delay { duration: fin }, vec![], tag);
        }
        let p2_end = hg.graph.len();
        (base..p1_end, p1_end..p2_end)
    }
}

/// One block compiles to a single chunk on this chunk grid — nothing for
/// the cross-phase pipeline to thread.
fn single_chunk(bytes: u64, chunk: u64) -> bool {
    ring::chunk_sizes(bytes, chunk).len() == 1
}

/// Dependencies for rank r's phase-3 allgather chunks in a hierarchical
/// AllGather. Rank r's ring block is its *gathered group*: node j's copy
/// of rank r's contribution sits at group offset j·msg. Each consumer
/// chunk is decomposed into per-source-node segments, projected into the
/// inter coordinate space (rank r's contribution occupies
/// [r·msg, (r+1)·msg) there) and looked up in the node's source-extended
/// arrival map. The locally resident copy (j == node_k) needs no
/// dependency.
#[allow(clippy::too_many_arguments)]
fn group_entry_deps(
    map: &ChunkMap,
    node_k: usize,
    r: usize,
    off: u64,
    sizes: &[u64],
    msg: u64,
    nn: usize,
    stride: u64,
) -> Vec<Vec<TaskId>> {
    let mut out = Vec::with_capacity(sizes.len());
    let mut pos = off;
    for &sz in sizes {
        let (lo, hi) = (pos, pos + sz);
        pos = hi;
        let mut deps: Vec<TaskId> = Vec::new();
        let mut x = lo;
        while x < hi {
            let j = (x / msg) as usize;
            let seg_end = hi.min((j as u64 + 1) * msg);
            if j != node_k && j < nn {
                let base = j as u64 * stride + r as u64 * msg;
                let y0 = x - j as u64 * msg;
                let y1 = seg_end - j as u64 * msg;
                deps.extend(map.producers(base + y0, base + y1));
            }
            x = seg_end;
        }
        deps.sort_unstable();
        deps.dedup();
        out.push(deps);
    }
    out
}

/// The pipelined-fold flow path covers NVLink-ring intra phases only:
/// the staged PCIe path double-buffers across slots and the
/// halving-doubling family strides — neither is a FIFO chunk chain.
fn flow_intra_ok<'a>(
    intra_ext: &[(PathId, u64, u64)],
    algos: impl Iterator<Item = &'a Algo>,
) -> bool {
    intra_ext.iter().all(|(p, _, _)| *p == PathId::Nvlink)
        && algos.into_iter().all(|a| *a == Algo::Ring)
}

/// Model for one active path (parallel lookup into an `intra_models`
/// result).
fn model_for(models: &[(PathId, PathModel)], p: PathId) -> PathModel {
    models
        .iter()
        .find(|(q, _)| *q == p)
        .map(|(_, m)| *m)
        .expect("model for every active path")
}

/// [`group_entry_deps`]' time-domain mirror for the pipelined-fold flow
/// path: per-chunk readiness of rank `r`'s gathered group on the
/// representative node (node 0 — its own copy is locally resident, so
/// segment j = 0 contributes no wait).
#[allow(clippy::too_many_arguments)]
fn group_entry_times(
    map: &flow::TimeMap,
    r: usize,
    off: u64,
    sizes: &[u64],
    msg: u64,
    nn: usize,
    stride: u64,
) -> Vec<SimTime> {
    let mut out = Vec::with_capacity(sizes.len());
    let mut pos = off;
    for &sz in sizes {
        let (lo, hi) = (pos, pos + sz);
        pos = hi;
        let mut t = SimTime::ZERO;
        let mut x = lo;
        while x < hi {
            let j = (x / msg) as usize;
            let seg_end = hi.min((j as u64 + 1) * msg);
            if j != 0 && j < nn {
                let base = j as u64 * stride + r as u64 * msg;
                let y0 = x - j as u64 * msg;
                let y1 = seg_end - j as u64 * msg;
                for rt in map.ready_for_chunks(base + y0, &[y1 - y0]) {
                    t = t.max(rt);
                }
            }
            x = seg_end;
        }
        out.push(t);
    }
    out
}

/// Naive baseline for the cluster: ONE flat ring over every global GPU,
/// NVLink inside a node, a single NIC at each node boundary — what you
/// get by feeding the global rank list to the single-node ring scheduler.
/// The hierarchical lowering must beat its makespan (all NICs stripe in
/// parallel instead of serializing the whole vector through one uplink
/// per boundary).
pub fn flat_ring_allreduce(
    cluster: &Cluster,
    calib: &Calibration,
    msg_bytes: u64,
) -> Result<SimTime> {
    anyhow::ensure!(cluster.n_nodes() >= 2, "flat ring baseline needs ≥2 nodes");
    let nn = cluster.n_nodes();
    let nl = cluster.gpus_per_node();
    let ng = nn * nl;
    let spec = &cluster.spec.node;
    let nv = calib.nvlink_model(CollectiveKind::AllReduce, nl, spec.nvlink_unidir_bps());
    let nic = calib.rdma_model(spec.nic_unidir_bps(), ng);
    let hop_extra = SimTime::from_secs_f64(cluster.spec.fabric.hop_latency_us * 1e-6);

    let mut pool = cluster.pool.clone();
    let mut graph = TaskGraph::new();
    let crosses = |r: usize| (r % nl) == nl - 1;
    let proto: Vec<ResourceId> = (0..ng)
        .map(|r| {
            let cap = if crosses(r) { nic.rate_cap } else { nv.rate_cap };
            pool.add(format!("proto.flatring.gpu{r}"), cap)
        })
        .collect();

    let block = msg_bytes.div_ceil(ng as u64);
    let sizes = ring::chunk_sizes(block, nv.chunk_bytes);

    // One ring step for sender r: gate latency, FIFO-chunked transfer;
    // `reduce` marks the ReduceScatter half, where the consumer must
    // combine each arrival (same reduce_after accounting as
    // GraphBuilder::send_block / HierGraph::send_inter, so the baseline
    // pays the same reduce amplification the hierarchical path does).
    let mut send_step = |graph: &mut TaskGraph,
                         r: usize,
                         deps_pc: &[Vec<TaskId>],
                         reduce: bool|
     -> Vec<TaskId> {
        let (k, g) = cluster.locate(r);
        let nxt = (r + 1) % ng;
        let (k2, g2) = cluster.locate(nxt);
        let mut route_base = vec![proto[r]];
        if k == k2 {
            route_base.push(cluster.node(k).nvlink_up[g]);
            route_base.push(cluster.node(k).nvlink_down[g2]);
        } else {
            route_base.extend(cluster.uplink_route(k, g, k2, g2));
        }
        let model = if crosses(r) { &nic } else { &nv };
        let mut lat = model.step_latency;
        if crosses(r) {
            lat = lat + hop_extra;
        }
        if reduce {
            lat = lat + model.reduce_step_latency;
        }
        let gate = if lat > SimTime::ZERO {
            Some(graph.add(
                TaskKind::Delay { duration: lat },
                deps_pc.first().cloned().unwrap_or_default(),
            ))
        } else {
            None
        };
        let mut prev_egress: Option<TaskId> = None;
        let mut arrivals = Vec::with_capacity(sizes.len());
        for (c, &bytes) in sizes.iter().enumerate() {
            let mut deps = deps_pc.get(c).cloned().unwrap_or_default();
            if let Some(gt) = gate {
                deps.push(gt);
            }
            if let Some(pe) = prev_egress {
                deps.push(pe);
            }
            let t = graph.add(
                TaskKind::Transfer {
                    bytes,
                    route: route_base.clone(),
                    weight: 1.0,
                    latency: SimTime::ZERO,
                    rate_cap: f64::INFINITY,
                },
                deps,
            );
            prev_egress = Some(t);
            // Cross-node arrivals pay the consumer combine (exactly as
            // send_inter does); NVLink's in-fabric reduce is inside its
            // fitted B_eff, mirroring send_block.
            let arrival = if reduce && bytes > 0 && crosses(r) {
                graph.add(
                    TaskKind::Delay {
                        duration: SimTime::for_transfer(bytes, calib.reduce_bps),
                    },
                    vec![t],
                )
            } else {
                t
            };
            arrivals.push(arrival);
        }
        arrivals
    };

    let mut prev: Vec<Vec<TaskId>> = vec![Vec::new(); ng];
    for s in 0..2 * (ng - 1) {
        let reduce = s < ng - 1;
        let mut arrs = Vec::with_capacity(ng);
        for r in 0..ng {
            let deps: Vec<Vec<TaskId>> = if s == 0 {
                Vec::new()
            } else {
                prev[(r + ng - 1) % ng].iter().map(|t| vec![*t]).collect()
            };
            arrs.push(send_step(&mut graph, r, &deps, reduce));
        }
        prev = arrs;
    }
    let sched = Engine::new(&pool).run(&graph)?;
    Ok(sched.makespan)
}

// ---------------------------------------------------------------------
// Graph-assembly plumbing.
// ---------------------------------------------------------------------

/// Chunk-aligned dep lists from per-node final-arrival lists.
fn chunked_deps(finals: &[Vec<TaskId>]) -> Vec<Vec<Vec<TaskId>>> {
    finals
        .iter()
        .map(|f| f.iter().map(|t| vec![*t]).collect())
        .collect()
}

/// Owns the growing (pool, graph) pair plus the inter-tier protocol
/// resources; intra phases borrow it back out through [`GraphBuilder`].
struct HierGraph<'c> {
    cluster: &'c Cluster,
    pool: ResourcePool,
    graph: TaskGraph,
    n_local: usize,
    inter_model: PathModel,
    hop_latency: SimTime,
    /// `[node][stripe]` single-put-stream cap of that NIC's uplink.
    stripe_proto: Vec<Vec<ResourceId>>,
    reduce_bps: f64,
    /// Folded pricing: per-stripe stand-in uplink routes over node 0's
    /// NIC legs plus the scaled spine share (replaces
    /// [`Cluster::uplink_route`] when set).
    fold_routes: Option<Vec<Vec<ResourceId>>>,
    /// The scaled spine-share resource of the folded pool.
    fold_spine: Option<ResourceId>,
    /// Partial-symmetry folding: per-stripe live rate cap from
    /// [`Cluster::fold_symmetry`] (`f64::INFINITY` for pristine
    /// stripes). The folded pool rebuilds node 0 at *nominal* caps, so a
    /// degraded NIC leg anywhere in the cluster is priced by capping the
    /// stand-in stripe's flows instead — the folded ring runs at the
    /// slowest class member's pace, exactly like the exact graph's
    /// slowest-node-paced ring.
    fold_rate_caps: Option<Vec<f64>>,
    /// Fair-share weight for every Transfer this lowering emits
    /// (copied from [`ClusterCollective::weight`]).
    weight: f64,
}

impl<'c> HierGraph<'c> {
    fn new(cc: &ClusterCollective<'c>) -> Self {
        Self::onto(cc, cc.cluster.pool.clone(), TaskGraph::new())
    }

    /// Build onto an existing (pool, graph): the lowering's private
    /// stripe-protocol resources are appended to `pool`, its tasks to
    /// `graph` — several enqueued cluster collectives fuse into one DES
    /// launch this way (the hierarchical mirror of
    /// [`GraphBuilder::onto`]).
    fn onto(cc: &ClusterCollective<'c>, mut pool: ResourcePool, graph: TaskGraph) -> Self {
        let nn = cc.cluster.n_nodes();
        let nl = cc.n_local;
        let spec = &cc.cluster.spec.node;
        let inter_model = cc.calib.rdma_model(spec.nic_unidir_bps(), nn.max(2));
        let hop_latency =
            SimTime::from_secs_f64(cc.cluster.spec.fabric.hop_latency_us * 1e-6);
        let stripe_proto = (0..nn)
            .map(|k| {
                (0..nl)
                    .map(|g| {
                        pool.add(
                            format!("proto.inter.node{k}.nic{g}"),
                            inter_model.rate_cap,
                        )
                    })
                    .collect()
            })
            .collect();
        HierGraph {
            cluster: cc.cluster,
            pool,
            graph,
            n_local: nl,
            inter_model,
            hop_latency,
            stripe_proto,
            reduce_bps: cc.calib.reduce_bps,
            fold_routes: None,
            fold_spine: None,
            fold_rate_caps: None,
            weight: cc.weight,
        }
    }

    /// Folded variant: the pool holds node 0's resources plus one
    /// spine-share stand-in ([`Cluster::folded_pool`]); protocol
    /// resources exist only for the representative node, and inter sends
    /// route over the fold routes regardless of the `src`/`dst` indices
    /// they are called with.
    fn folded(cc: &ClusterCollective<'c>) -> Self {
        let (mut pool, fold_spine) = cc
            .cluster
            .folded_pool()
            .expect("folded pricing needs a multi-node cluster");
        let spec = &cc.cluster.spec.node;
        let nl = cc.n_local;
        let inter_model = cc
            .calib
            .rdma_model(spec.nic_unidir_bps(), cc.cluster.n_nodes().max(2));
        let hop_latency =
            SimTime::from_secs_f64(cc.cluster.spec.fabric.hop_latency_us * 1e-6);
        let stripe_proto = vec![(0..nl)
            .map(|g| {
                pool.add(format!("proto.inter.node0.nic{g}"), inter_model.rate_cap)
            })
            .collect()];
        let node0 = cc.cluster.node(0);
        let fold_routes = (0..nl)
            .map(|g| {
                let mut r = Vec::with_capacity(5);
                if spec.path_contention {
                    r.push(node0.pcie_up[g]);
                }
                r.push(node0.nic_up[g]);
                r.push(fold_spine);
                r.push(node0.nic_down[g]);
                if spec.path_contention {
                    r.push(node0.pcie_down[g]);
                }
                r
            })
            .collect();
        HierGraph {
            cluster: cc.cluster,
            pool,
            graph: TaskGraph::new(),
            n_local: nl,
            inter_model,
            hop_latency,
            stripe_proto,
            reduce_bps: cc.calib.reduce_bps,
            fold_routes: Some(fold_routes),
            fold_spine: Some(fold_spine),
            fold_rate_caps: Some(
                cc.cluster
                    .fold_symmetry()
                    .expect("folded pricing requires fold symmetry")
                    .stripe_rates,
            ),
            weight: cc.weight,
        }
    }

    /// Per-stripe live rate cap of the folded stand-in ring
    /// (`f64::INFINITY` on exact graphs and pristine stripes).
    fn fold_rate_cap(&self, stripe: usize) -> f64 {
        self.fold_rate_caps
            .as_ref()
            .map_or(f64::INFINITY, |c| c[stripe])
    }

    fn barrier(&mut self, deps: Vec<TaskId>) -> TaskId {
        self.graph.add(TaskKind::Barrier, deps)
    }

    fn inter_chunks(&self, bytes: u64) -> usize {
        ring::chunk_sizes(bytes, self.inter_model.chunk_bytes).len()
    }

    /// Lend the (pool, graph) pair to a per-node [`GraphBuilder`] for one
    /// intra phase on node `k`.
    fn with_node_builder<F>(&mut self, k: usize, models: &[(PathId, PathModel)], f: F)
    where
        F: FnOnce(&mut GraphBuilder<'_>),
    {
        let pool = std::mem::take(&mut self.pool);
        let graph = std::mem::take(&mut self.graph);
        let mut b = GraphBuilder::onto(
            self.cluster.node(k),
            self.n_local,
            models,
            self.reduce_bps,
            pool,
            graph,
        );
        b.set_weight(self.weight);
        f(&mut b);
        let (pool, graph) = b.into_parts();
        self.pool = pool;
        self.graph = graph;
    }

    /// Emit one inter-node block send `src_node → dst_node` on `stripe`
    /// (chunk-pipelined, FIFO egress, per-step gate latency — the
    /// cross-node mirror of [`GraphBuilder::send_block`]).
    #[allow(clippy::too_many_arguments)]
    fn send_inter(
        &mut self,
        src_node: usize,
        dst_node: usize,
        stripe: usize,
        bytes: u64,
        deps_per_chunk: &[Vec<TaskId>],
        reduce_after: bool,
        tag: u32,
    ) -> Vec<TaskId> {
        let sizes = ring::chunk_sizes(bytes, self.inter_model.chunk_bytes);
        debug_assert!(deps_per_chunk.is_empty() || deps_per_chunk.len() == sizes.len());
        let step_lat = self.inter_model.step_latency
            + self.hop_latency
            + if reduce_after {
                self.inter_model.reduce_step_latency
            } else {
                SimTime::ZERO
            };
        let gate: Option<TaskId> = if step_lat > SimTime::ZERO {
            let gate_deps = deps_per_chunk.first().cloned().unwrap_or_default();
            Some(self.graph.add_tagged(
                TaskKind::Delay { duration: step_lat },
                gate_deps,
                tag,
            ))
        } else {
            None
        };
        let mut prev_egress: Option<TaskId> = None;
        let mut arrivals = Vec::with_capacity(sizes.len());
        for (c, &chunk_bytes) in sizes.iter().enumerate() {
            let mut deps = deps_per_chunk.get(c).cloned().unwrap_or_default();
            if let Some(g) = gate {
                deps.push(g);
            }
            if let Some(pe) = prev_egress {
                deps.push(pe);
            }
            let mut route = vec![self.stripe_proto[src_node][stripe]];
            match &self.fold_routes {
                Some(rs) => route.extend(rs[stripe].iter().copied()),
                None => route.extend(
                    self.cluster
                        .uplink_route(src_node, stripe, dst_node, stripe),
                ),
            }
            let t = self.graph.add_tagged(
                TaskKind::Transfer {
                    bytes: chunk_bytes,
                    route,
                    weight: self.weight,
                    latency: SimTime::ZERO,
                    // Partial-symmetry folding: the stand-in route is
                    // nominal, so the degraded class member's pace lands
                    // as a per-flow cap.
                    rate_cap: self.fold_rate_cap(stripe),
                },
                deps,
                tag,
            );
            prev_egress = Some(t);
            let arrival = if reduce_after && chunk_bytes > 0 {
                self.graph.add_tagged(
                    TaskKind::Delay {
                        duration: SimTime::for_transfer(chunk_bytes, self.reduce_bps),
                    },
                    vec![t],
                    tag,
                )
            } else {
                t
            };
            arrivals.push(arrival);
        }
        arrivals
    }

    /// Bottleneck rate of one folded stripe route, *excluding* the shared
    /// spine (the stripe's private legs plus the protocol cap). Used both
    /// to price flow segments and to decide whether the spine could ever
    /// be the bottleneck.
    fn fold_stripe_rate(&self, stripe: usize) -> f64 {
        let spine = self.fold_spine.expect("fold helpers need a folded graph");
        let route = &self.fold_routes.as_ref().expect("folded graph")[stripe];
        flow::bottleneck_rate(
            route
                .iter()
                .filter(|id| **id != spine)
                .map(|id| self.pool.capacity(*id)),
            self.inter_model.rate_cap.min(self.fold_rate_cap(stripe)),
        )
    }

    /// Flow fast path is sound iff every active stripe stays uncontended:
    /// FIFO egress keeps at most one in-flight transfer per stripe, so
    /// with `a` active stripes the spine carries ≤ `a` concurrent flows —
    /// if each stripe's private bottleneck is ≤ spine_cap / a, the
    /// max–min solution is each flow at its private rate and the chain
    /// has a closed form.
    fn fold_flow_eligible(&self, inter_ext: &[(StripeId, u64, u64)]) -> bool {
        let Some(spine) = self.fold_spine else {
            return false;
        };
        let active: Vec<usize> = inter_ext
            .iter()
            .filter(|(_, _, len)| *len > 0)
            .map(|(sid, _, _)| sid.0 as usize)
            .collect();
        if active.is_empty() {
            return false;
        }
        let fair = self.pool.capacity(spine) / active.len() as f64;
        active.iter().all(|&s| {
            let r = self.fold_stripe_rate(s);
            // A dead stripe (rate 0) has no closed form — and no DES
            // price either; run_folded falls back to exact before here.
            r > 0.0 && r <= fair
        })
    }

    /// [`flow::ChainSpec`] for `steps` ring hops on `stripe` with the
    /// same per-hop gate and reduce semantics as [`send_inter`].
    fn fold_chain_spec(&self, stripe: usize, steps: usize, reduce: bool) -> flow::ChainSpec {
        let gate = self.inter_model.step_latency
            + self.hop_latency
            + if reduce {
                self.inter_model.reduce_step_latency
            } else {
                SimTime::ZERO
            };
        flow::ChainSpec {
            steps,
            gate,
            rate_bps: self.fold_stripe_rate(stripe),
            reduce_bps: reduce.then_some(self.reduce_bps),
        }
    }

    /// Price one folded ring phase on `stripe` as a closed-form chunk
    /// chain: `steps` hops over the stripe's private bottleneck rate.
    /// `ready` carries per-chunk readiness from a previous chain (empty
    /// slice ⇒ all chunks ready at phase start) and `egress0` the time
    /// the stripe's shared egress goes idle (back-to-back phases on one
    /// stripe reuse the same wire). Returns the final arrivals plus the
    /// new egress-idle time.
    fn fold_flow_phase(
        &self,
        stripe: usize,
        block: u64,
        steps: usize,
        reduce: bool,
        ready: &[SimTime],
        egress0: SimTime,
    ) -> (Vec<SimTime>, SimTime) {
        let sizes = ring::chunk_sizes(block, self.inter_model.chunk_bytes);
        let spec = self.fold_chain_spec(stripe, steps, reduce);
        let zeros;
        let ready = if ready.is_empty() {
            zeros = vec![SimTime::ZERO; sizes.len()];
            &zeros
        } else {
            ready
        };
        let (steps, egress) = flow::chain_steps_from(&spec, &sizes, ready, egress0);
        (steps.into_iter().next_back().expect("steps >= 1"), egress)
    }

    /// Folded ring reduce-scatter on one stripe: nn−1 self-chained
    /// representative sends. Under symmetry, node 0's step-(s−1) arrival
    /// coincides with what its ring predecessor would deliver, so each
    /// step's receive-side dependency is the previous step's own arrival;
    /// the producer-map/barrier entry stands in for every node's phase-1
    /// output (node 0's is identical to all of them). Returns the final
    /// (reduced) per-chunk arrivals of the owned sub-block.
    fn fold_ring_reduce_scatter(
        &mut self,
        stripe: usize,
        s_off: u64,
        len: u64,
        producer: Option<&ChunkMap>,
        entry: Option<TaskId>,
        tag: u32,
    ) -> Vec<TaskId> {
        let nn = self.cluster.n_nodes();
        let sub = len.div_ceil(nn as u64);
        let sizes = ring::chunk_sizes(sub, self.inter_model.chunk_bytes);
        let mut prev: Vec<TaskId> = Vec::new();
        for s in 0..nn - 1 {
            let blk = ring::rs_send_block(0, s, nn) as u64;
            let mut deps: Vec<Vec<TaskId>> = match producer {
                Some(map) => map.deps_for_chunks(s_off + blk * sub, &sizes),
                None => {
                    let e = entry.expect("barriered fold needs an entry barrier");
                    vec![vec![e]; sizes.len()]
                }
            };
            if s > 0 {
                for (c, d) in deps.iter_mut().enumerate() {
                    d.push(prev[c]);
                }
            }
            // The exact compiler's extra s == nn−2 receiver-shard dep is
            // node 0's own producer output here — already present.
            prev = self.send_inter(0, 0, stripe, sub, &deps, true, tag);
        }
        prev
    }

    /// Consume the accumulated (pool, graph) into a [`CompiledHier`] with
    /// the given phase id-ranges; phase 3 is everything emitted after the
    /// inter phase, watermarked at the graph's current length.
    fn into_compiled(self, p1_range: Range<usize>, p2_range: Range<usize>) -> CompiledHier {
        let p3_range = p2_range.end..self.graph.len();
        CompiledHier {
            pool: self.pool,
            graph: self.graph,
            p1_range,
            p2_range,
            p3_range,
        }
    }

    /// Ring reduce-scatter over the nodes on one stripe. `entry[k]` gates
    /// node k's first send (its phase-1 output). Returns per-node final
    /// (reduced-at-node) arrival ids, chunk-aligned.
    fn inter_ring_reduce_scatter(
        &mut self,
        stripe: usize,
        bytes: u64,
        entry: &[TaskId],
        tag: u32,
    ) -> Vec<Vec<TaskId>> {
        let nn = self.cluster.n_nodes();
        let sub = bytes.div_ceil(nn as u64);
        let n_chunks = self.inter_chunks(sub);
        let mut prev: Vec<Vec<TaskId>> = vec![Vec::new(); nn];
        for s in 0..nn - 1 {
            let mut arr = Vec::with_capacity(nn);
            for k in 0..nn {
                let deps: Vec<Vec<TaskId>> = (0..n_chunks)
                    .map(|c| {
                        let mut d = vec![entry[k]];
                        if s > 0 {
                            d.push(prev[ring::prev(k, nn)][c]);
                        }
                        d
                    })
                    .collect();
                arr.push(self.send_inter(k, ring::next(k, nn), stripe, sub, &deps, true, tag));
            }
            prev = arr;
        }
        // The block fully reduced AT node k arrived from prev(k).
        (0..nn).map(|k| prev[ring::prev(k, nn)].clone()).collect()
    }

    /// As [`Self::inter_ring_reduce_scatter`], but gated per chunk on the
    /// byte-interval producers of each step's ring block instead of a
    /// whole-phase entry barrier: node k's step-s send carries the
    /// stripe's sub-block (k − s) mod nn (`ring::rs_send_block`), so each
    /// of its chunks starts the moment the phase-1 chunks producing those
    /// bytes — plus the previous ring step's same-chunk arrival — finish.
    /// `producers[k]` is node k's phase-1 map over the message
    /// coordinates; `s_off` is the stripe extent's offset there.
    fn inter_ring_reduce_scatter_piped(
        &mut self,
        stripe: usize,
        s_off: u64,
        bytes: u64,
        producers: &[ChunkMap],
        tag: u32,
    ) -> Vec<Vec<TaskId>> {
        let nn = self.cluster.n_nodes();
        let sub = bytes.div_ceil(nn as u64);
        let sizes = ring::chunk_sizes(sub, self.inter_model.chunk_bytes);
        let mut prev: Vec<Vec<TaskId>> = vec![Vec::new(); nn];
        for s in 0..nn - 1 {
            let mut arr = Vec::with_capacity(nn);
            for k in 0..nn {
                let blk = ring::rs_send_block(k, s, nn) as u64;
                let mut deps = producers[k].deps_for_chunks(s_off + blk * sub, &sizes);
                if s > 0 {
                    for (c, d) in deps.iter_mut().enumerate() {
                        d.push(prev[ring::prev(k, nn)][c]);
                    }
                }
                if s == nn - 2 {
                    // Final step: the consumer combine at next(k) folds
                    // the RECEIVER's own phase-1 shard into the block.
                    // At earlier steps that dependency is imposed by the
                    // receiver's own next-step send of the same block,
                    // but the fully reduced block is never sent again —
                    // without this the final combine (and everything the
                    // pipeline hangs off it) could run before the
                    // receiver's intra phase produced those bytes.
                    let recv =
                        producers[ring::next(k, nn)].deps_for_chunks(s_off + blk * sub, &sizes);
                    for (d, mut r) in deps.iter_mut().zip(recv) {
                        d.append(&mut r);
                    }
                }
                arr.push(self.send_inter(k, ring::next(k, nn), stripe, sub, &deps, true, tag));
            }
            prev = arr;
        }
        (0..nn).map(|k| prev[ring::prev(k, nn)].clone()).collect()
    }

    /// Ring allgather over the nodes on one stripe, returning the arrival
    /// chunk ids per `[step][node]`. With `start[k]` holding node k's own
    /// block, step s delivers to node m the block that originated at node
    /// (m − 1 − s) mod nn; when `start` holds the reduce-scatter outputs
    /// (node k owns block (k+1) mod nn), step s delivers block
    /// (m − s) mod nn. Callers that pipeline use this attribution to
    /// register arrivals in their availability maps.
    fn inter_ring_allgather_steps(
        &mut self,
        stripe: usize,
        bytes: u64,
        start: &[Vec<Vec<TaskId>>],
        tag: u32,
    ) -> Vec<Vec<Vec<TaskId>>> {
        let nn = self.cluster.n_nodes();
        let mut at: Vec<Vec<Vec<TaskId>>> = start.to_vec();
        let mut steps: Vec<Vec<Vec<TaskId>>> = Vec::with_capacity(nn - 1);
        for _s in 0..nn - 1 {
            let mut new_at: Vec<Vec<Vec<TaskId>>> = vec![Vec::new(); nn];
            let mut arrived: Vec<Vec<TaskId>> = vec![Vec::new(); nn];
            for k in 0..nn {
                let a = self.send_inter(k, ring::next(k, nn), stripe, bytes, &at[k], false, tag);
                arrived[ring::next(k, nn)] = a.clone();
                new_at[ring::next(k, nn)] = a.iter().map(|t| vec![*t]).collect();
            }
            at = new_at;
            steps.push(arrived);
        }
        steps
    }

    /// Flattened [`Self::inter_ring_allgather_steps`]: every arrival at
    /// each node (the stripe's per-node completion set).
    fn inter_ring_allgather(
        &mut self,
        stripe: usize,
        bytes: u64,
        start: &[Vec<Vec<TaskId>>],
        tag: u32,
    ) -> Vec<Vec<TaskId>> {
        let nn = self.cluster.n_nodes();
        let steps = self.inter_ring_allgather_steps(stripe, bytes, start, tag);
        let mut done: Vec<Vec<TaskId>> = vec![Vec::new(); nn];
        for per_node in &steps {
            for (m, a) in per_node.iter().enumerate() {
                done[m].extend(a.iter().copied());
            }
        }
        done
    }

    /// Pipeline chain node0 → node1 → … on one stripe (Broadcast's inter
    /// phase); `entry_per_chunk[c]` gates chunk c's first hop. Returns
    /// per-node arrival ids (node 0 empty).
    fn inter_chain(
        &mut self,
        stripe: usize,
        bytes: u64,
        entry_per_chunk: &[Vec<TaskId>],
        tag: u32,
    ) -> Vec<Vec<TaskId>> {
        let nn = self.cluster.n_nodes();
        debug_assert_eq!(entry_per_chunk.len(), self.inter_chunks(bytes));
        let mut at: Vec<Vec<TaskId>> = entry_per_chunk.to_vec();
        let mut done: Vec<Vec<TaskId>> = vec![Vec::new(); nn];
        for hop in 0..nn - 1 {
            let a = self.send_inter(hop, hop + 1, stripe, bytes, &at, false, tag);
            done[hop + 1] = a.clone();
            at = a.iter().map(|t| vec![*t]).collect();
        }
        done
    }
}

// ---------------------------------------------------------------------
// Intra-phase ring loops with explicit entry dependencies (the flat
// builders in allgather.rs / reduce_scatter.rs assume locally resident
// data; hierarchical phases must gate on the previous phase instead).
// ---------------------------------------------------------------------

/// Ring reduce-scatter over the builder's node; every step-0 chunk gates
/// on `entry`. Returns per-rank final (reduced) arrival ids.
fn intra_ring_reduce_scatter(
    b: &mut GraphBuilder<'_>,
    path: PathId,
    block: u64,
    entry: &[TaskId],
    tag: u32,
) -> Vec<Vec<TaskId>> {
    let n = b.n;
    let n_chunks = b.chunks_for(path, block).len();
    let mut prev: Vec<Vec<TaskId>> = vec![Vec::new(); n];
    for s in 0..n - 1 {
        let mut arr = Vec::with_capacity(n);
        for r in 0..n {
            let deps: Vec<Vec<TaskId>> = (0..n_chunks)
                .map(|c| {
                    let mut d = entry.to_vec();
                    if s > 0 {
                        d.push(prev[ring::prev(r, n)][c]);
                    }
                    d
                })
                .collect();
            arr.push(b.send_block(path, r, ring::next(r, n), block, &deps, true, true, tag));
        }
        prev = arr;
    }
    (0..n).map(|r| prev[ring::prev(r, n)].clone()).collect()
}

/// Dispatch one intra allgather phase to its selected lowering. Both
/// lowerings take the same per-rank/per-chunk entry shape (each rank
/// opens with its own block) and return every arrival at each rank, so
/// the three-phase compilers are algorithm-agnostic past this point.
fn intra_allgather_dispatch(
    b: &mut GraphBuilder<'_>,
    al: Algo,
    path: PathId,
    block: u64,
    entry: &[Vec<Vec<TaskId>>],
    tag: u32,
) -> Vec<Vec<TaskId>> {
    match al {
        Algo::HalvingDoubling => algo::doubling_allgather(b, path, block, entry, tag),
        _ => intra_ring_allgather(b, path, block, entry, tag),
    }
}

/// Ring allgather over the builder's node; `entry[r][c]` gates chunk c of
/// rank r's first send (rank r opens with ring block r). Barriered
/// callers replicate one barrier across chunks; pipelined callers thread
/// the per-chunk producers of each rank's block. Returns every arrival at
/// each rank.
fn intra_ring_allgather(
    b: &mut GraphBuilder<'_>,
    path: PathId,
    block: u64,
    entry: &[Vec<Vec<TaskId>>],
    tag: u32,
) -> Vec<Vec<TaskId>> {
    let n = b.n;
    debug_assert!(entry
        .iter()
        .all(|per_rank| per_rank.len() == b.chunks_for(path, block).len()));
    let mut at: Vec<Vec<Vec<TaskId>>> = entry.to_vec();
    let mut done: Vec<Vec<TaskId>> = vec![Vec::new(); n];
    for _s in 0..n - 1 {
        let mut new_at: Vec<Vec<Vec<TaskId>>> = vec![Vec::new(); n];
        for r in 0..n {
            let a = b.send_block(path, r, ring::next(r, n), block, &at[r], true, false, tag);
            done[ring::next(r, n)].extend(a.iter().copied());
            new_at[ring::next(r, n)] = a.iter().map(|t| vec![*t]).collect();
        }
        at = new_at;
    }
    done
}

/// Pipelined chain broadcast 0 → 1 → … → n−1 on the builder's node.
/// Returns per-rank arrival ids (rank 0, the source, stays empty).
fn intra_chain_broadcast(
    b: &mut GraphBuilder<'_>,
    path: PathId,
    msg: u64,
    entry: &[TaskId],
    tag: u32,
) -> Vec<Vec<TaskId>> {
    let n = b.n;
    let n_chunks = b.chunks_for(path, msg).len();
    let mut at: Vec<Vec<TaskId>> = (0..n_chunks).map(|_| entry.to_vec()).collect();
    let mut arrivals_at: Vec<Vec<TaskId>> = vec![Vec::new(); n];
    for hop in 0..n - 1 {
        let a = b.send_block(path, hop, hop + 1, msg, &at, true, false, tag);
        arrivals_at[hop + 1] = a.clone();
        at = a.iter().map(|t| vec![*t]).collect();
    }
    arrivals_at
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::Preset;
    use crate::topology::cluster::ClusterSpec;

    fn cluster(nn: usize) -> Cluster {
        Cluster::build(&ClusterSpec::new(nn, Preset::H800.spec()))
    }

    fn cc(c: &Cluster, kind: CollectiveKind) -> ClusterCollective<'_> {
        ClusterCollective::new(c, Calibration::h800(), kind, c.gpus_per_node())
    }

    /// n_nodes = 1 must be bit-identical to the flat single-node DES.
    #[test]
    fn single_node_is_bit_identical_to_flat_path() {
        let c = cluster(1);
        let flat_topo = crate::topology::Topology::build(&Preset::H800.spec());
        for kind in [CollectiveKind::AllReduce, CollectiveKind::AllGather] {
            let hier = cc(&c, kind);
            let shares = Shares::from_pcts(&[
                (PathId::Nvlink, 83.0),
                (PathId::Pcie, 10.0),
                (PathId::Rdma, 7.0),
            ]);
            let tiers = TierShares::single_node(shares.clone());
            let msg = 64u64 << 20;
            let h = hier.run(msg, &tiers, 4).unwrap();
            let f = MultipathCollective::new(&flat_topo, Calibration::h800(), kind, 8)
                .run_elem(msg, &shares, 4)
                .unwrap();
            assert_eq!(h.total, f.outcome.total, "{kind}: degenerate case diverged");
            assert_eq!(h.intra_times, f.path_times());
            assert!(h.inter_times.is_empty());
        }
    }

    /// The tentpole claim: hierarchical AllReduce beats the naive flat
    /// ring over the NIC fabric, at 2 and 4 nodes.
    #[test]
    fn hierarchical_allreduce_beats_flat_ring() {
        for nn in [2usize, 4] {
            let c = cluster(nn);
            let col = cc(&c, CollectiveKind::AllReduce);
            let tiers = TierShares::new(Shares::nvlink_only(), c.gpus_per_node());
            let msg = 256u64 << 20;
            let hier = col.run(msg, &tiers, 4).unwrap();
            let flat = flat_ring_allreduce(&c, &Calibration::h800(), msg).unwrap();
            assert!(
                hier.total < flat,
                "nn={nn}: hierarchical {} not faster than flat ring {}",
                hier.total,
                flat
            );
            // The win must be structural (NIC striping), not marginal.
            assert!(
                hier.total.as_secs_f64() * 2.0 < flat.as_secs_f64(),
                "nn={nn}: expected ≥2× from striping, got {} vs {}",
                hier.total,
                flat
            );
        }
    }

    /// Every lowered operator produces a sane multi-node report: nonzero
    /// total, per-stripe times for all stripes, phases ordered.
    #[test]
    fn all_lowered_ops_simulate_on_two_nodes() {
        let c = cluster(2);
        for kind in [
            CollectiveKind::AllReduce,
            CollectiveKind::AllGather,
            CollectiveKind::ReduceScatter,
            CollectiveKind::Broadcast,
        ] {
            let col = cc(&c, kind);
            let tiers = TierShares::new(Shares::nvlink_only(), 8);
            let rep = col.run(32 << 20, &tiers, 4).unwrap();
            assert!(rep.total > SimTime::ZERO, "{kind}: zero makespan");
            assert_eq!(rep.inter_times.len(), 8, "{kind}: missing stripe times");
            assert!(
                rep.inter_phase.end > SimTime::ZERO,
                "{kind}: no inter phase"
            );
            assert!(rep.inter_phase.end <= rep.total);
            assert!(rep.inter_phase.start <= rep.inter_phase.end);
            assert!(
                rep.intra_phase1.end <= rep.inter_phase.end,
                "{kind}: inter phase cannot end before the phase-1 output feeding it"
            );
            assert!(rep.algbw_gbps() > 0.0);
        }
    }

    /// The tentpole: chunk-pipelined phase joins beat the whole-phase
    /// barriers for every multi-chunk lowering, and the phase spans show
    /// the overlap (the inter phase starts before phase 1 has drained).
    #[test]
    fn pipelined_beats_barriered_and_overlaps_phases() {
        for nn in [2usize, 4] {
            let c = cluster(nn);
            for kind in [CollectiveKind::AllReduce, CollectiveKind::AllGather] {
                let tiers = TierShares::new(Shares::nvlink_only(), 8);
                let msg = 64u64 << 20;
                let pipe = cc(&c, kind).run(msg, &tiers, 4).unwrap();
                let bar = cc(&c, kind)
                    .with_pipeline(false)
                    .run(msg, &tiers, 4)
                    .unwrap();
                assert!(
                    pipe.total < bar.total,
                    "nn={nn} {kind}: pipelined {} not under barriered {}",
                    pipe.total,
                    bar.total
                );
                if kind == CollectiveKind::AllReduce {
                    assert!(
                        pipe.inter_phase.start < pipe.intra_phase1.end,
                        "nn={nn} {kind}: no overlap — inter starts {} after phase 1 ends {}",
                        pipe.inter_phase.start,
                        pipe.intra_phase1.end
                    );
                }
            }
        }
    }

    /// Both lowerings move exactly the same bytes over exactly the same
    /// resources — pipelining reorders time, never traffic.
    #[test]
    fn pipelined_and_barriered_conserve_resource_bytes() {
        let c = cluster(2);
        for kind in [
            CollectiveKind::AllReduce,
            CollectiveKind::AllGather,
            CollectiveKind::ReduceScatter,
            CollectiveKind::Broadcast,
        ] {
            let tiers = TierShares::new(Shares::nvlink_only(), 8);
            let pipe = cc(&c, kind).compile(24 << 20, &tiers, 4).unwrap();
            let bar = cc(&c, kind)
                .with_pipeline(false)
                .compile(24 << 20, &tiers, 4)
                .unwrap();
            assert_eq!(
                pipe.graph.resource_bytes(),
                bar.graph.resource_bytes(),
                "{kind}: lowering changed per-resource traffic"
            );
        }
    }

    /// Single-chunk schedules must compile to the barriered graph
    /// task-for-task — the degeneracy contract of the pipelined lowering.
    #[test]
    fn single_chunk_pipelined_graph_equals_barriered() {
        let c = cluster(2);
        let mut calib = Calibration::h800();
        calib.chunk_bytes = 1 << 40; // every block is one chunk
        for kind in [
            CollectiveKind::AllReduce,
            CollectiveKind::AllGather,
            CollectiveKind::ReduceScatter,
            CollectiveKind::Broadcast,
        ] {
            let tiers = TierShares::new(Shares::nvlink_only(), 8);
            let mk = |pipeline: bool| {
                ClusterCollective::new(&c, calib.clone(), kind, 8)
                    .with_pipeline(pipeline)
                    .compile(8 << 20, &tiers, 4)
                    .unwrap()
            };
            assert_eq!(
                mk(true).graph,
                mk(false).graph,
                "{kind}: single-chunk pipelined graph diverged from barriered"
            );
        }
    }

    /// Under `auto`, latency-bound intra phases leave ring (tree /
    /// halving-doubling selected from the phase's own message size), yet
    /// the lowering moves exactly the same total traffic and simulates
    /// to a sane multi-node report; in the bandwidth-bound regime auto
    /// compiles the ring graph identically (ring stays the default for
    /// direct constructions, so everything else in this suite is
    /// untouched).
    #[test]
    fn auto_intra_algos_conserve_traffic_and_ring_large_messages() {
        let c = cluster(2);
        let sum = |g: &CompiledHier| g.graph.resource_bytes().values().sum::<u64>();
        let mut non_ring_seen = false;
        for kind in [
            CollectiveKind::AllReduce,
            CollectiveKind::AllGather,
            CollectiveKind::ReduceScatter,
            CollectiveKind::Broadcast,
        ] {
            let tiers = TierShares::new(Shares::nvlink_only(), 8);
            let msg = 2u64 << 20; // small phases → auto leaves ring
            let auto_cc = ClusterCollective::new(&c, Calibration::h800(), kind, 8)
                .with_algo(AlgoSpec::Auto);
            let a = auto_cc.compile(msg, &tiers, 4).unwrap();
            let r = cc(&c, kind).compile(msg, &tiers, 4).unwrap();
            assert_eq!(sum(&a), sum(&r), "{kind}: auto changed total traffic");
            non_ring_seen |= a.graph != r.graph;
            let rep = auto_cc.run(msg, &tiers, 4).unwrap();
            assert!(rep.total > SimTime::ZERO, "{kind}: zero makespan under auto");
            assert_eq!(rep.inter_times.len(), 8, "{kind}: missing stripe times");
        }
        assert!(
            non_ring_seen,
            "auto never left ring at 2 MiB — the dispatch is dead"
        );
        // Bandwidth-bound: auto and ring compile the identical graph.
        let tiers = TierShares::new(Shares::nvlink_only(), 8);
        let big = 256u64 << 20;
        let a = ClusterCollective::new(&c, Calibration::h800(), CollectiveKind::AllReduce, 8)
            .with_algo(AlgoSpec::Auto)
            .compile(big, &tiers, 4)
            .unwrap();
        let r = cc(&c, CollectiveKind::AllReduce).compile(big, &tiers, 4).unwrap();
        assert_eq!(a.graph, r.graph, "auto must ring the 256 MiB lowering");
    }

    /// More nodes at fixed message size must not get cheaper: the
    /// inter-node ring grows while per-NIC bandwidth stays fixed.
    #[test]
    fn allreduce_scales_monotonically_in_nodes() {
        let msg = 64u64 << 20;
        let mut prev = SimTime::ZERO;
        for nn in [2usize, 4, 8] {
            let c = cluster(nn);
            let col = cc(&c, CollectiveKind::AllReduce);
            let tiers = TierShares::new(Shares::nvlink_only(), 8);
            let t = col.run(msg, &tiers, 4).unwrap().total;
            assert!(
                t >= prev,
                "nn={nn}: {t} faster than {prev} at fewer nodes"
            );
            prev = t;
        }
    }

    /// A degraded NIC shows up in the inter-only measurable as a slower
    /// stripe — the signal the stripe tuner equalizes away.
    #[test]
    fn degraded_nic_slows_its_stripe() {
        let mut c = cluster(2);
        let bad = c.node(0).nic_up[2];
        c.pool.scale_capacity(bad, 0.25);
        let col = cc(&c, CollectiveKind::AllGather);
        let even = Shares::even(&crate::balancer::tier::stripes(8));
        let times = col.run_inter_only(32 << 20, &even).unwrap();
        assert_eq!(times.len(), 8);
        let t2 = times.iter().find(|t| t.0 == StripeId(2)).unwrap().1;
        let t0 = times.iter().find(|t| t.0 == StripeId(0)).unwrap().1;
        assert!(
            t2.as_secs_f64() > 1.5 * t0.as_secs_f64(),
            "degraded stripe {} vs healthy {}",
            t2,
            t0
        );
    }

    /// Spine oversubscription throttles the striped inter phase.
    #[test]
    fn oversubscribed_spine_slows_inter_phase() {
        let full = cluster(4);
        let mut spec = ClusterSpec::new(4, Preset::H800.spec());
        spec.fabric = crate::topology::cluster::InterNodeFabric::oversubscribed(16.0);
        let tight = Cluster::build(&spec);
        let even = Shares::even(&crate::balancer::tier::stripes(8));
        let msg = 64u64 << 20;
        let t_full = cc(&full, CollectiveKind::AllGather)
            .run_inter_only(msg, &even)
            .unwrap()
            .iter()
            .map(|t| t.1)
            .max()
            .unwrap();
        let t_tight = cc(&tight, CollectiveKind::AllGather)
            .run_inter_only(msg, &even)
            .unwrap()
            .iter()
            .map(|t| t.1)
            .max()
            .unwrap();
        assert!(
            t_tight > t_full,
            "16:1 spine {} not slower than full bisection {}",
            t_tight,
            t_full
        );
    }

    /// The fold soundness claim: on a healthy symmetric cluster the
    /// reduced representative graph (and, barriered, the closed-form flow
    /// segments) prices within 5% of the full per-node DES — while
    /// emitting strictly fewer tasks.
    #[test]
    fn folded_pricing_matches_exact_at_small_scale() {
        for nn in [2usize, 4] {
            let c = cluster(nn);
            for kind in [
                CollectiveKind::AllReduce,
                CollectiveKind::AllGather,
                CollectiveKind::ReduceScatter,
            ] {
                for pipeline in [true, false] {
                    let tiers = TierShares::new(Shares::nvlink_only(), 8);
                    let msg = 32u64 << 20;
                    let exact = cc(&c, kind)
                        .with_pipeline(pipeline)
                        .run(msg, &tiers, 4)
                        .unwrap();
                    let folded = cc(&c, kind)
                        .with_pipeline(pipeline)
                        .with_pricing(PricingMode::Folded)
                        .run(msg, &tiers, 4)
                        .unwrap();
                    assert!(!exact.folded);
                    assert!(
                        folded.folded,
                        "nn={nn} {kind} pipeline={pipeline}: fold did not engage"
                    );
                    assert!(
                        folded.tasks < exact.tasks,
                        "nn={nn} {kind} pipeline={pipeline}: folded graph not smaller \
                         ({} vs {})",
                        folded.tasks,
                        exact.tasks
                    );
                    let (e, f) = (exact.total.as_secs_f64(), folded.total.as_secs_f64());
                    assert!(
                        (e - f).abs() <= 0.05 * e,
                        "nn={nn} {kind} pipeline={pipeline}: folded {f} vs exact {e}"
                    );
                }
            }
        }
    }

    /// Broken *non-NIC* symmetry (a degraded NVLink lane) must force the
    /// exact graph even under `Folded`/`Auto` — per-stripe rate caps only
    /// absorb NIC-leg deviations, so anything else voids the fold's
    /// one-representative premise.
    #[test]
    fn fold_falls_back_on_broken_symmetry() {
        let mut c = cluster(2);
        let bad = c.node(0).nvlink_up[2];
        c.pool.scale_capacity(bad, 0.25);
        let col = cc(&c, CollectiveKind::AllReduce).with_pricing(PricingMode::Folded);
        assert!(
            !col.fold_eligible(),
            "NVLink-degraded cluster priced as symmetric"
        );
        let tiers = TierShares::new(Shares::nvlink_only(), 8);
        let rep = col.run(8 << 20, &tiers, 4).unwrap();
        assert!(!rep.folded, "fold engaged on an NVLink-degraded cluster");
    }

    /// Partial symmetry: a degraded NIC leg no longer breaks the fold —
    /// the affected stripe is priced through its per-stripe rate cap,
    /// within the usual 5% of the exact graph, in both lowerings, and
    /// visibly slower than the healthy cluster.
    #[test]
    fn fold_prices_degraded_nic_within_tolerance() {
        let mut c = cluster(4);
        let bad = c.node(2).nic_up[3];
        c.pool.scale_capacity(bad, 0.5);
        let healthy = cluster(4);
        let tiers = TierShares::new(Shares::nvlink_only(), 8);
        let msg = 32u64 << 20;
        for pipeline in [true, false] {
            let col = cc(&c, CollectiveKind::AllReduce)
                .with_pipeline(pipeline)
                .with_pricing(PricingMode::Folded);
            assert!(col.fold_eligible(), "degraded NIC left the fold classes");
            let folded = col.run(msg, &tiers, 4).unwrap();
            assert!(
                folded.folded,
                "pipeline={pipeline}: degraded NIC broke the fold"
            );
            let exact = cc(&c, CollectiveKind::AllReduce)
                .with_pipeline(pipeline)
                .run(msg, &tiers, 4)
                .unwrap();
            let (e, f) = (exact.total.as_secs_f64(), folded.total.as_secs_f64());
            assert!(
                (e - f).abs() <= 0.05 * e,
                "pipeline={pipeline}: folded {f} vs exact {e}"
            );
            let h = cc(&healthy, CollectiveKind::AllReduce)
                .with_pipeline(pipeline)
                .with_pricing(PricingMode::Folded)
                .run(msg, &tiers, 4)
                .unwrap();
            assert!(
                folded.total > h.total,
                "pipeline={pipeline}: degraded fold {} not slower than healthy {}",
                folded.total,
                h.total
            );
        }
    }

    /// A *dead* NIC leg stays inside the fold classes, but a live share
    /// routed over it can never finish — `run` silently prices that
    /// combination exact, and folds again once the stripe is deactivated.
    #[test]
    fn fold_skips_dead_stripe_with_live_share() {
        let mut c = cluster(2);
        let bad = c.node(1).nic_up[5];
        c.pool.scale_capacity(bad, 0.0);
        let col = cc(&c, CollectiveKind::AllGather).with_pricing(PricingMode::Folded);
        assert!(
            col.fold_eligible(),
            "dead NIC leg should stay inside the fold classes"
        );
        let tiers = TierShares::new(Shares::nvlink_only(), 8);
        assert!(
            col.run_folded(8 << 20, &tiers, 4).unwrap().is_none(),
            "fold produced a price for traffic on a dead stripe"
        );
        let rerouted = tiers.without_stripe(StripeId(5)).unwrap();
        let rep = col.run(8 << 20, &rerouted, 4).unwrap();
        assert!(rep.folded, "healthy-class fold lost after stripe deactivation");
    }

    /// The Auto fold threshold is configurable — the `fold_min_nodes`
    /// run-config key lands here through the builder (clamped ≥2).
    #[test]
    fn fold_threshold_is_configurable() {
        let c = cluster(4);
        let tiers = TierShares::new(Shares::nvlink_only(), 8);
        let rep = cc(&c, CollectiveKind::AllReduce)
            .with_pricing(PricingMode::Auto)
            .with_fold_min_nodes(4)
            .run(8 << 20, &tiers, 4)
            .unwrap();
        assert!(rep.folded, "lowered threshold did not fold at 4 nodes");
        let rep = cc(&c, CollectiveKind::AllReduce)
            .with_pricing(PricingMode::Auto)
            .with_fold_min_nodes(5)
            .run(8 << 20, &tiers, 4)
            .unwrap();
        assert!(!rep.folded, "4-node cluster folded below a 5-node threshold");
    }

    /// An empty fault timeline takes `run_under_faults` through the fold:
    /// the chaos loop's between-fault steps price sublinearly, and the
    /// answer is bit-identical to the plain folded run.
    #[test]
    fn empty_timeline_faulted_run_folds() {
        let c = cluster(4);
        let tiers = TierShares::new(Shares::nvlink_only(), 8);
        let col = cc(&c, CollectiveKind::AllReduce)
            .with_pricing(PricingMode::Auto)
            .with_fold_min_nodes(4);
        let run = col.run_under_faults(8 << 20, &tiers, 4, &[]).unwrap();
        assert_eq!(run.failed_tasks, 0);
        assert!(run.report.folded, "empty-timeline faulted run did not fold");
        let rep = col.run(8 << 20, &tiers, 4).unwrap();
        assert_eq!(run.report.total, rep.total);
    }

    /// `Auto` pins small clusters to the exact graph and folds at scale.
    #[test]
    fn auto_pricing_folds_only_at_scale() {
        let small = cluster(2);
        let col = cc(&small, CollectiveKind::AllReduce).with_pricing(PricingMode::Auto);
        assert!(col.fold_eligible());
        let tiers = TierShares::new(Shares::nvlink_only(), 8);
        assert!(!col.run(8 << 20, &tiers, 4).unwrap().folded);

        let big = cluster(FOLD_AUTO_MIN_NODES);
        let col = cc(&big, CollectiveKind::AllReduce).with_pricing(PricingMode::Auto);
        let rep = col.run(8 << 20, &tiers, 4).unwrap();
        assert!(rep.folded, "Auto did not fold at {FOLD_AUTO_MIN_NODES} nodes");
        assert!(rep.total > SimTime::ZERO);
    }
}
