//! Ring AllGather — timing-graph construction.
//!
//! N−1 steps; at step `s` rank `r` forwards block `(r−s) mod n` to
//! `r+1`. Chunks pipeline across steps: chunk `c` of step `s` becomes
//! sendable at `r` the moment the same chunk arrived from `r−1` at step
//! `s−1`, so for large messages every rank's egress stays busy and the
//! completion approaches `(n−1)·α + (n−1)·S / B_eff`.

use super::ring;
use super::schedule::GraphBuilder;
use crate::links::PathId;
use crate::sim::TaskId;

/// Append the AllGather tasks for `block` bytes per rank on `path`.
pub fn build_tasks(b: &mut GraphBuilder<'_>, path: PathId, block: u64, tag: u32) {
    let n = b.n;
    // arrivals[r][c]: "chunk c of the block r received at step s-1".
    let mut prev_arrivals: Vec<Vec<TaskId>> = vec![Vec::new(); n];
    for s in 0..n - 1 {
        let mut arrivals: Vec<Vec<TaskId>> = Vec::with_capacity(n);
        for r in 0..n {
            let deps: Vec<Vec<TaskId>> = if s == 0 {
                Vec::new()
            } else {
                prev_arrivals[ring::prev(r, n)]
                    .iter()
                    .map(|t| vec![*t])
                    .collect()
            };
            let a = b.send_block(path, r, ring::next(r, n), block, &deps, true, false, tag);
            arrivals.push(a);
        }
        prev_arrivals = arrivals;
    }
}

#[cfg(test)]
mod tests {
    use crate::collectives::algo::Algo;
    use crate::collectives::schedule::{simulate, MultipathSpec, PathAssignment};
    use crate::collectives::CollectiveKind;
    use crate::config::presets::Preset;
    use crate::links::calib::Calibration;
    use crate::links::PathId;
    use crate::topology::Topology;

    fn run(n: usize, mib: u64) -> f64 {
        let topo = Topology::build(&Preset::H800.spec());
        let kind = CollectiveKind::AllGather;
        let model =
            Calibration::h800().nvlink_model(kind, n, topo.spec.nvlink_unidir_bps());
        let s = mib << 20;
        let spec = MultipathSpec {
            kind,
            n,
            msg_bytes: s,
            algo: Algo::Ring,
            paths: vec![PathAssignment {
                path: PathId::Nvlink,
                bytes: s,
                model,
            }],
            weight: 1.0,
        };
        let out = simulate(&topo, &spec, 60e9).unwrap();
        kind.algbw_gbps(s, out.total.as_secs_f64())
    }

    /// The NVLink-only DES must land on the paper's NCCL AllGather column
    /// (Table 2) across the reported sizes — the calibration target.
    #[test]
    fn matches_paper_nccl_column() {
        let cases = [
            (2, 32, 103.0),
            (2, 256, 132.0),
            (4, 64, 46.0),
            (4, 256, 49.0),
            (8, 32, 20.0),
            (8, 128, 21.0),
        ];
        for (n, mib, paper) in cases {
            let got = run(n, mib);
            let err = (got - paper).abs() / paper;
            assert!(
                err < 0.10,
                "AG n={n} {mib}MB: sim {got:.1} GB/s vs paper {paper} ({:.0}% off)",
                err * 100.0
            );
        }
    }

    /// Larger messages achieve higher algbw (latency amortization).
    #[test]
    fn algbw_monotonic_in_size() {
        let seq: Vec<f64> = [32u64, 64, 128, 256].iter().map(|m| run(8, *m)).collect();
        for w in seq.windows(2) {
            assert!(w[1] >= w[0] * 0.99, "algbw regressed with size: {seq:?}");
        }
    }
}
