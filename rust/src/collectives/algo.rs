//! Pluggable lowering algorithms with size-adaptive auto-selection.
//!
//! The paper's §5.3 blames ring latency amplification ("2(N−1) sequential
//! steps") for the small-message regime, and §6 names tree-based
//! algorithms as the fix. This module makes the *algorithm* a first-class
//! tuned dimension, orthogonal to the path-share dimension the balancer
//! owns:
//!
//! * [`Algo`] — the lowering algorithms: the canonical NCCL [`Algo::Ring`],
//!   the binomial [`Algo::Tree`] (AllReduce, Broadcast), and
//!   [`Algo::HalvingDoubling`] (recursive-halving ReduceScatter,
//!   recursive-doubling AllGather, and their AllReduce composition).
//! * [`lower`] — the lowering registry: the ONE dispatch point every
//!   consumer (flat sim, exec timing face, stream scheduler's fused
//!   launches, hierarchical `compile_onto`) flows through.
//!   Non-power-of-two rank counts fall back to ring here, once
//!   ([`resolve`]), so the per-algorithm builders can assume pow2.
//! * [`predict`] / [`select_analytic`] — an analytic α–β cost model per
//!   (kind, algo, n), seeded from the calibrated [`PathModel`] (the same
//!   α/B_eff/ρ constants the DES charges), for cheap candidate ordering.
//! * [`AlgoTable`] — the tuner: `algo = "auto"` consults the analytic
//!   model and, whenever it predicts a switch away from ring, refines the
//!   shortlist with DES-backed probes; the winner is cached per
//!   (operator, message-size-bucket) — the crossover table NCCL's tuner
//!   keeps, discovered instead of shipped.
//!
//! Fixed overrides come via the `algo` TOML key / `--algo` CLI flag
//! ([`AlgoSpec`]). `algo = "ring"` reproduces the pre-algorithm schedules
//! bit-identically (the registry then calls exactly the old builders).

use super::schedule::GraphBuilder;
use super::CollectiveKind;
use crate::balancer::shares::Shares;
use crate::collectives::multipath::MultipathCollective;
use crate::links::{PathId, PathModel};
use crate::sim::{SimTime, TaskId};
use anyhow::Result;
use std::collections::HashMap;
use std::fmt;
use std::str::FromStr;

/// Streaming efficiency of the halving-doubling lowerings relative to the
/// path's calibrated single-stream rate. Ring keeps every transfer a
/// contiguous block — that is *why* NCCL rings win the bandwidth-bound
/// regime — while recursive halving/doubling moves strided half-vector
/// segments whose scatter/gather addressing costs a slice of the
/// streaming rate. Charged per-transfer (task-level `rate_cap`) so the
/// DES and the analytic model agree on the crossover.
pub const HD_EFF: f64 = 0.85;

/// A collective lowering algorithm.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algo {
    /// Canonical NCCL ring / chain schedules — bandwidth-optimal,
    /// 2(N−1) (AllReduce) sequential latency steps.
    Ring,
    /// Binomial tree (AllReduce: reduce sweep + broadcast sweep;
    /// Broadcast: binomial fan-out). log₂N latency steps, but non-leaf
    /// links carry the whole vector.
    Tree,
    /// Recursive halving (ReduceScatter) / doubling (AllGather) and their
    /// AllReduce composition: ring's wire bytes in log₂N steps, at a
    /// strided-segment streaming penalty ([`HD_EFF`]).
    HalvingDoubling,
}

impl Algo {
    pub const ALL: [Algo; 3] = [Algo::Ring, Algo::Tree, Algo::HalvingDoubling];
}

impl fmt::Display for Algo {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Algo::Ring => "ring",
            Algo::Tree => "tree",
            Algo::HalvingDoubling => "halving_doubling",
        };
        write!(f, "{s}")
    }
}

impl FromStr for Algo {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s.to_ascii_lowercase().replace('-', "_").as_str() {
            "ring" => Algo::Ring,
            "tree" => Algo::Tree,
            "halving_doubling" | "halvingdoubling" | "hd" => Algo::HalvingDoubling,
            other => anyhow::bail!("unknown algorithm '{other}' (ring|tree|halving_doubling)"),
        })
    }
}

/// Algorithm selection policy: tuned per size bucket, or pinned.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AlgoSpec {
    /// Size-adaptive selection via [`AlgoTable`] (the default).
    #[default]
    Auto,
    /// Fixed override (`algo = "ring"` in TOML, `--algo ring` on the
    /// CLI). Still [`resolve`]d, so an unsupported (kind, algo) pair
    /// falls back to ring instead of failing.
    Fixed(Algo),
}

impl fmt::Display for AlgoSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AlgoSpec::Auto => write!(f, "auto"),
            AlgoSpec::Fixed(a) => write!(f, "{a}"),
        }
    }
}

impl FromStr for AlgoSpec {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        if s.eq_ignore_ascii_case("auto") {
            Ok(AlgoSpec::Auto)
        } else {
            Ok(AlgoSpec::Fixed(s.parse()?))
        }
    }
}

/// The algorithms registered for (kind, n), ring first (ring is the
/// incumbent and the tie-break winner). Non-power-of-two rank counts
/// have only ring — the single fallback gate of the registry.
pub fn candidates(kind: CollectiveKind, n: usize) -> &'static [Algo] {
    if !n.is_power_of_two() {
        return &[Algo::Ring];
    }
    match kind {
        CollectiveKind::AllReduce => &[Algo::Ring, Algo::Tree, Algo::HalvingDoubling],
        CollectiveKind::AllGather | CollectiveKind::ReduceScatter => {
            &[Algo::Ring, Algo::HalvingDoubling]
        }
        CollectiveKind::Broadcast => &[Algo::Ring, Algo::Tree],
        CollectiveKind::AllToAll => &[Algo::Ring],
    }
}

/// Resolve a requested algorithm to a registered lowering: unsupported
/// (kind, algo) pairs and non-power-of-two rank counts fall back to ring.
pub fn resolve(kind: CollectiveKind, algo: Algo, n: usize) -> Algo {
    if candidates(kind, n).contains(&algo) {
        algo
    } else {
        Algo::Ring
    }
}

/// log2 bucket of a message size — the granularity at which both the
/// share tuner and the algorithm tuner cache their decisions (§3.2.2:
/// the optimum "can vary with data size").
pub fn size_class(msg_bytes: u64) -> u32 {
    msg_bytes.max(1).next_power_of_two().trailing_zeros()
}

// ---------------------------------------------------------------------
// Analytic α–β cost model.
// ---------------------------------------------------------------------

/// Analytic completion estimate for one (kind, algo) lowering of `msg`
/// bytes over `n` ranks on a path with the given calibrated model. Seeded
/// entirely from the calibration (α = `step_latency`, ρ =
/// `reduce_step_latency`, B = `rate_cap`, plus the staged consumer
/// combine on PCIe) so ordering tracks the DES; [`AlgoTable`] refines
/// close calls with real DES probes.
pub fn predict(
    kind: CollectiveKind,
    algo: Algo,
    n: usize,
    model: &PathModel,
    msg: u64,
    reduce_bps: f64,
    path: PathId,
) -> SimTime {
    let algo = resolve(kind, algo, n);
    let b = model.rate_cap;
    let alpha = model.step_latency.as_secs_f64();
    let rho = model.reduce_step_latency.as_secs_f64();
    let l = n.max(2).trailing_zeros() as f64;
    let nf = n as f64;
    let s = msg as f64;
    // Staged-path consumer combine (send_block charges it on PCIe only).
    let combine = |bytes: f64| {
        if path == PathId::Pcie {
            bytes / reduce_bps
        } else {
            0.0
        }
    };
    use Algo::*;
    use CollectiveKind::*;
    let secs = match (kind, algo) {
        (AllReduce, Ring) => {
            (nf - 1.0) * (alpha + rho)
                + (nf - 1.0) * alpha
                + 2.0 * (nf - 1.0) / nf * s / b
                + combine((nf - 1.0) / nf * s)
        }
        // Root carries log₂N full vectors in AND out (chunk-pipelined
        // sweeps overlap, so the root's lane is the bottleneck).
        (AllReduce, Tree) => l * (alpha + rho) + l * alpha + l * s / b + combine(l * s),
        (AllReduce, HalvingDoubling) => {
            l * (alpha + rho)
                + l * alpha
                + 2.0 * (nf - 1.0) / nf * s / (HD_EFF * b)
                + combine((nf - 1.0) / nf * s)
        }
        (AllGather, Ring) => (nf - 1.0) * alpha + (nf - 1.0) * s / b,
        (AllGather, HalvingDoubling) => l * alpha + (nf - 1.0) * s / (HD_EFF * b),
        (ReduceScatter, Ring) => {
            (nf - 1.0) * (alpha + rho)
                + (nf - 1.0) / nf * s / b
                + combine((nf - 1.0) / nf * s)
        }
        (ReduceScatter, HalvingDoubling) => {
            l * (alpha + rho)
                + (nf - 1.0) / nf * s / (HD_EFF * b)
                + combine((nf - 1.0) / nf * s)
        }
        // Pipelined chain streams the vector once past every hop.
        (Broadcast, Ring) => (nf - 1.0) * alpha + s / b,
        // Binomial root sends log₂N full copies down its one lane.
        (Broadcast, Tree) => l * alpha + l * s / b,
        (AllToAll, Ring) => (nf - 1.0) * alpha + (nf - 1.0) / nf * s / b,
        _ => unreachable!("resolve() yields only registered (kind, algo) pairs"),
    };
    SimTime::from_secs_f64(secs)
}

/// Analytic argmin over the registered candidates (ring-first tie-break).
/// The hierarchical compiler uses this per intra-node phase, at the
/// phase's own message size (a DES probe there would recurse).
pub fn select_analytic(
    kind: CollectiveKind,
    n: usize,
    model: &PathModel,
    msg: u64,
    reduce_bps: f64,
    path: PathId,
) -> Algo {
    let mut best = Algo::Ring;
    let mut best_t = SimTime::from_nanos(u64::MAX);
    for &a in candidates(kind, n) {
        let t = predict(kind, a, n, model, msg, reduce_bps, path);
        if t < best_t {
            best = a;
            best_t = t;
        }
    }
    best
}

// ---------------------------------------------------------------------
// Degraded-mode (MTBF-aware) expected cost.
// ---------------------------------------------------------------------

/// Chaos-aware tuning term: the fraction of wall time the fabric spends
/// degraded under a `[chaos]` MTBF/MTTR fault process, and the bandwidth
/// factor while degraded. [`AlgoTable::with_degraded_mode`] folds it into
/// candidate ordering so the tuner prefers lowerings whose *one-lane-down*
/// algbw is higher even when their peak algbw narrowly loses — at
/// steady-state fault rates (100k-GPU scale) expected goodput, not peak,
/// is the objective.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DegradedMode {
    /// Fraction of time spent degraded: MTTR / (MTBF + MTTR), the renewal
    /// process's unavailability duty cycle.
    pub duty: f64,
    /// Bandwidth multiplier while degraded, in (0, 1].
    pub factor: f64,
}

impl DegradedMode {
    /// The canonical chaos case: one of `n_lanes` identical NIC stripes
    /// down (recovery has folded its share into the survivors), so the
    /// aggregate bandwidth factor is `(n−1)/n`. Degenerates to no
    /// degradation for a single lane — one lane down is an outage, not a
    /// degraded mode, and outage time is priced by the recovery policies.
    pub fn one_stripe_down(n_lanes: usize, mtbf_s: f64, mttr_s: f64) -> Self {
        assert!(mtbf_s > 0.0 && mttr_s >= 0.0, "MTBF > 0, MTTR ≥ 0");
        let n = n_lanes as f64;
        DegradedMode {
            duty: mttr_s / (mtbf_s + mttr_s),
            factor: if n_lanes <= 1 { 1.0 } else { (n - 1.0) / n },
        }
    }
}

/// Expected completion time under a degraded-mode duty cycle: the
/// duty-weighted mixture of [`predict`] at peak bandwidth and at
/// `factor ×` bandwidth. Latency (α/ρ) terms are bandwidth-independent,
/// so the mixture inflates exactly each candidate's *bandwidth* term by
/// `(1 − duty) + duty / factor` — candidates with smaller bandwidth
/// coefficients (ring's `2(N−1)/N` vs tree's `log₂N`) lose less, which
/// is precisely the one-lane-down-algbw preference.
#[allow(clippy::too_many_arguments)]
pub fn predict_degraded(
    kind: CollectiveKind,
    algo: Algo,
    n: usize,
    model: &PathModel,
    msg: u64,
    reduce_bps: f64,
    path: PathId,
    dm: &DegradedMode,
) -> SimTime {
    let peak = predict(kind, algo, n, model, msg, reduce_bps, path);
    if dm.duty <= 0.0 || dm.factor >= 1.0 {
        return peak;
    }
    let mut weak = *model;
    weak.rate_cap = model.rate_cap * dm.factor;
    let degraded = predict(kind, algo, n, &weak, msg, reduce_bps, path);
    SimTime::from_secs_f64(
        (1.0 - dm.duty) * peak.as_secs_f64() + dm.duty * degraded.as_secs_f64(),
    )
}

// ---------------------------------------------------------------------
// The AlgoTable tuner.
// ---------------------------------------------------------------------

/// One tuned bucket: the chosen algorithm plus the evidence behind it.
#[derive(Debug, Clone)]
pub struct AlgoEntry {
    pub algo: Algo,
    /// Analytic estimates per candidate (always populated under auto).
    pub analytic: Vec<(Algo, SimTime)>,
    /// DES probe results; empty when the analytic model already picked
    /// ring (the incumbent needs no confirmation) or the choice is fixed.
    pub probes: Vec<(Algo, SimTime)>,
}

/// Per-(operator, size-bucket) algorithm selection cache — the NCCL-tuner
/// analogue. Under [`AlgoSpec::Auto`] a bucket's first call seeds the
/// analytic estimates; if they predict a switch away from ring, the
/// shortlist (candidates within 2× of the analytic best) is probed on the
/// real DES and the measured winner is cached. Probe time is returned so
/// the communicator can account it (beside, not inside, the Algorithm-1
/// profiling time).
#[derive(Debug, Default)]
pub struct AlgoTable {
    spec: AlgoSpec,
    entries: HashMap<(CollectiveKind, u32), AlgoEntry>,
    /// Chaos-aware objective: when set, Auto ranks candidates by
    /// duty-weighted expected time ([`predict_degraded`]) instead of peak
    /// time, and decides purely analytically — a DES probe measures the
    /// *healthy* fabric, which is exactly what MTBF-aware tuning must not
    /// trust alone.
    degraded: Option<DegradedMode>,
}

impl AlgoTable {
    pub fn new(spec: AlgoSpec) -> Self {
        AlgoTable {
            spec,
            entries: HashMap::new(),
            degraded: None,
        }
    }

    /// Fold a degraded-mode term into Auto's candidate ordering. Clears
    /// cached entries — decisions made against the peak objective are
    /// stale under the expected-goodput one.
    pub fn with_degraded_mode(mut self, dm: DegradedMode) -> Self {
        self.degraded = Some(dm);
        self.entries.clear();
        self
    }

    /// The degraded-mode term, when configured.
    pub fn degraded_mode(&self) -> Option<DegradedMode> {
        self.degraded
    }

    /// The policy this table runs.
    pub fn spec(&self) -> AlgoSpec {
        self.spec
    }

    /// The cached decision for (kind, size bucket), if already tuned.
    pub fn chosen(&self, kind: CollectiveKind, msg_bytes: u64) -> Option<Algo> {
        self.entries
            .get(&(kind, size_class(msg_bytes)))
            .map(|e| e.algo)
    }

    /// Full evidence for (kind, size bucket), if already tuned.
    pub fn entry(&self, kind: CollectiveKind, msg_bytes: u64) -> Option<&AlgoEntry> {
        self.entries.get(&(kind, size_class(msg_bytes)))
    }

    /// Select (and cache) the algorithm for one (operator, size-bucket)
    /// under the given share distribution. Returns the choice plus the
    /// simulated time spent on DES probes (ZERO on cache hits, fixed
    /// specs, and analytic-ring conclusions).
    pub fn select(
        &mut self,
        mc: &MultipathCollective<'_>,
        msg_bytes: u64,
        shares: &Shares,
    ) -> Result<(Algo, SimTime)> {
        let key = (mc.kind, size_class(msg_bytes));
        if let Some(e) = self.entries.get(&key) {
            return Ok((e.algo, SimTime::ZERO));
        }
        let entry;
        let mut probe_time = SimTime::ZERO;
        match self.spec {
            AlgoSpec::Fixed(a) => {
                entry = AlgoEntry {
                    algo: resolve(mc.kind, a, mc.n),
                    analytic: Vec::new(),
                    probes: Vec::new(),
                };
            }
            AlgoSpec::Auto => {
                // Analytic seed: per candidate, the slowest active path
                // bounds the collective (paths run concurrently). With a
                // degraded mode configured, each path's estimate is the
                // duty-weighted expected time instead of the peak time.
                let extents = shares.to_extents(msg_bytes, crate::dtype::natural_align(msg_bytes));
                let analytic: Vec<(Algo, SimTime)> = candidates(mc.kind, mc.n)
                    .iter()
                    .map(|&a| {
                        let t = extents
                            .iter()
                            .filter(|(_, _, len)| *len > 0)
                            .map(|(p, _, len)| match &self.degraded {
                                Some(dm) => predict_degraded(
                                    mc.kind,
                                    a,
                                    mc.n,
                                    &mc.model(*p),
                                    *len,
                                    mc.calib.reduce_bps,
                                    *p,
                                    dm,
                                ),
                                None => predict(
                                    mc.kind,
                                    a,
                                    mc.n,
                                    &mc.model(*p),
                                    *len,
                                    mc.calib.reduce_bps,
                                    *p,
                                ),
                            })
                            .max()
                            .unwrap_or(SimTime::ZERO);
                        (a, t)
                    })
                    .collect();
                let (mut best, mut best_t) = analytic[0];
                for &(a, t) in &analytic[1..] {
                    if t < best_t {
                        best = a;
                        best_t = t;
                    }
                }
                if best == Algo::Ring || self.degraded.is_some() {
                    // Ring incumbent: won on the model it was calibrated
                    // against — no probe needed (this also keeps the
                    // bandwidth-bound buckets probe-free). Degraded mode:
                    // always decide analytically — a DES probe runs on
                    // the healthy fabric and would systematically favor
                    // peak-optimal picks.
                    entry = AlgoEntry {
                        algo: best,
                        analytic,
                        probes: Vec::new(),
                    };
                } else {
                    // A switch is predicted: confirm on the DES over the
                    // shortlist of plausible candidates.
                    let cutoff = SimTime::from_nanos(best_t.as_nanos().saturating_mul(2));
                    let mut probes = Vec::new();
                    for &(a, t) in &analytic {
                        if t <= cutoff {
                            let measured = mc.run_algo(msg_bytes, shares, a)?.total();
                            probe_time += measured;
                            probes.push((a, measured));
                        }
                    }
                    let (mut algo, mut algo_t) = probes[0];
                    for &(a, t) in &probes[1..] {
                        if t < algo_t {
                            algo = a;
                            algo_t = t;
                        }
                    }
                    entry = AlgoEntry {
                        algo,
                        analytic,
                        probes,
                    };
                }
            }
        }
        let algo = entry.algo;
        self.entries.insert(key, entry);
        Ok((algo, probe_time))
    }
}

// ---------------------------------------------------------------------
// The lowering registry.
// ---------------------------------------------------------------------

/// Emit one collective's tasks for `bytes` on `path` under `algo` — the
/// single dispatch point that replaced the hardcoded per-kind ring match
/// in `schedule::append_call`. Unsupported combinations and
/// non-power-of-two rank counts resolve to ring here.
pub fn lower(
    b: &mut GraphBuilder<'_>,
    kind: CollectiveKind,
    algo: Algo,
    path: PathId,
    bytes: u64,
    tag: u32,
) {
    use Algo::*;
    use CollectiveKind::*;
    match (kind, resolve(kind, algo, b.n)) {
        (AllReduce, Ring) => super::allreduce::build_tasks(b, path, bytes, tag),
        (AllReduce, Tree) => super::tree::build_allreduce(b, path, bytes, tag),
        (AllReduce, HalvingDoubling) => halving_doubling_allreduce(b, path, bytes, tag),
        (AllGather, Ring) => super::allgather::build_tasks(b, path, bytes, tag),
        (AllGather, HalvingDoubling) => {
            doubling_allgather(b, path, bytes, &[], tag);
        }
        (ReduceScatter, Ring) => super::reduce_scatter::build_tasks(b, path, bytes, tag),
        (ReduceScatter, HalvingDoubling) => {
            halving_reduce_scatter(b, path, bytes, &[], tag);
        }
        (Broadcast, Ring) => super::broadcast::build_tasks(b, path, bytes, tag),
        (Broadcast, Tree) => {
            super::tree::build_broadcast(b, path, bytes, &[], tag);
        }
        (AllToAll, Ring) => super::alltoall::build_tasks(b, path, bytes, tag),
        (kind, algo) => unreachable!("resolve() returned unregistered ({kind}, {algo})"),
    }
}

// ---------------------------------------------------------------------
// Halving-doubling lowerings.
// ---------------------------------------------------------------------

/// Recursive-halving ReduceScatter of a `msg`-byte vector: log₂N pairwise
/// exchange stages at rank distance N/2, N/4, …, 1; each stage sends the
/// half of the current working range the rank gives up (N/2^(k+1) blocks)
/// and reduces the arriving half. Stages join at per-rank reduction
/// barriers (the halving boundary *is* a reduce), matching the analytic
/// model's serialized-stage cost. Every send is capped at [`HD_EFF`] of
/// the path's streaming rate (strided segments).
///
/// `entry` gates every rank's first send (hierarchical phases pass the
/// previous phase's barrier; flat callers pass `&[]` for locally resident
/// data). Returns per-rank final arrival chunks — under the canonical
/// keep-the-half-containing-your-own-index scheme, rank `r` ends owning
/// block `r` (grid: `chunks_for(path, ceil(msg/n))`).
pub fn halving_reduce_scatter(
    b: &mut GraphBuilder<'_>,
    path: PathId,
    msg: u64,
    entry: &[TaskId],
    tag: u32,
) -> Vec<Vec<TaskId>> {
    let n = b.n;
    assert!(n.is_power_of_two(), "halving-doubling needs power-of-two ranks");
    let block = msg.div_ceil(n as u64);
    let stages = n.trailing_zeros() as usize;
    let cap = HD_EFF * b.model(path).rate_cap;
    // watermark[r]: "r has reduced everything received so far".
    let mut watermark: Vec<Vec<TaskId>> = vec![entry.to_vec(); n];
    let mut finals: Vec<Vec<TaskId>> = vec![Vec::new(); n];
    for k in 0..stages {
        let d = n >> (k + 1);
        let bytes = d as u64 * block;
        let n_chunks = b.chunks_for(path, bytes).len();
        let mut arr: Vec<Vec<TaskId>> = Vec::with_capacity(n);
        for r in 0..n {
            let deps: Vec<Vec<TaskId>> = vec![watermark[r].clone(); n_chunks];
            arr.push(b.send_block_capped(path, r, r ^ d, bytes, &deps, true, true, tag, cap));
        }
        let last = k == stages - 1;
        for r in 0..n {
            let arrived = arr[r ^ d].clone(); // arrival AT r is from its partner
            if last {
                // Final block: the last arrival joined with r's own
                // reduce watermark (earlier stages also contributed to
                // this block, and those combines live at r, not at the
                // sender — without the join the block could look final
                // before r reduced them in).
                finals[r] = arrived
                    .iter()
                    .map(|a| {
                        if watermark[r].is_empty() {
                            *a
                        } else {
                            let mut dd = vec![*a];
                            dd.extend(watermark[r].iter().copied());
                            b.graph.barrier(dd)
                        }
                    })
                    .collect();
            } else {
                let mut dd = watermark[r].clone();
                dd.extend(arrived.iter().copied());
                watermark[r] = vec![b.graph.barrier(dd)];
            }
        }
    }
    finals
}

/// Recursive-doubling AllGather of per-rank `block`-byte contributions:
/// log₂N pairwise exchange stages at distance 1, 2, …, N/2, each sending
/// the rank's whole current range (2^k blocks). `entry[r]` gates rank
/// r's stage-0 send per chunk of its own block (the shape hierarchical
/// phase-3 callers thread from their availability maps; `&[]` = locally
/// resident). Later stages join at per-rank barriers. Returns every
/// arrival at each rank.
pub fn doubling_allgather(
    b: &mut GraphBuilder<'_>,
    path: PathId,
    block: u64,
    entry: &[Vec<Vec<TaskId>>],
    tag: u32,
) -> Vec<Vec<TaskId>> {
    let n = b.n;
    assert!(n.is_power_of_two(), "halving-doubling needs power-of-two ranks");
    let stages = n.trailing_zeros() as usize;
    let cap = HD_EFF * b.model(path).rate_cap;
    let n0 = b.chunks_for(path, block).len();
    debug_assert!(entry.is_empty() || entry.len() == n);
    let mut watermark: Vec<Vec<TaskId>> = vec![Vec::new(); n];
    let mut done: Vec<Vec<TaskId>> = vec![Vec::new(); n];
    for k in 0..stages {
        let d = 1usize << k;
        let bytes = d as u64 * block;
        let n_chunks = b.chunks_for(path, bytes).len();
        let mut arr: Vec<Vec<TaskId>> = Vec::with_capacity(n);
        for r in 0..n {
            let deps: Vec<Vec<TaskId>> = if k == 0 {
                match entry.get(r) {
                    Some(e) if !e.is_empty() => {
                        debug_assert_eq!(e.len(), n0, "entry grid must match the block grid");
                        e.clone()
                    }
                    _ => Vec::new(),
                }
            } else {
                vec![watermark[r].clone(); n_chunks]
            };
            arr.push(b.send_block_capped(path, r, r ^ d, bytes, &deps, true, false, tag, cap));
        }
        for r in 0..n {
            let arrived = arr[r ^ d].clone();
            done[r].extend(arrived.iter().copied());
            let mut dd = watermark[r].clone();
            if k == 0 {
                if let Some(e) = entry.get(r) {
                    for c in e {
                        dd.extend(c.iter().copied());
                    }
                }
            }
            dd.extend(arrived.iter().copied());
            watermark[r] = vec![b.graph.barrier(dd)];
        }
    }
    done
}

/// Halving-doubling AllReduce: recursive-halving ReduceScatter feeding a
/// recursive-doubling AllGather of the reduced blocks.
pub fn halving_doubling_allreduce(b: &mut GraphBuilder<'_>, path: PathId, msg: u64, tag: u32) {
    let n = b.n as u64;
    let finals = halving_reduce_scatter(b, path, msg, &[], tag);
    let entry: Vec<Vec<Vec<TaskId>>> = finals
        .iter()
        .map(|f| f.iter().map(|t| vec![*t]).collect())
        .collect();
    doubling_allgather(b, path, msg.div_ceil(n), &entry, tag);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::schedule::{simulate, MultipathSpec, PathAssignment};
    use crate::config::presets::Preset;
    use crate::links::calib::Calibration;
    use crate::topology::Topology;

    fn nv_model(kind: CollectiveKind, n: usize) -> PathModel {
        let topo = Topology::build(&Preset::H800.spec());
        Calibration::h800().nvlink_model(kind, n, topo.spec.nvlink_unidir_bps())
    }

    fn run_fixed(kind: CollectiveKind, n: usize, msg: u64, algo: Algo) -> f64 {
        let topo = Topology::build(&Preset::H800.spec());
        let spec = MultipathSpec {
            kind,
            n,
            msg_bytes: msg,
            algo,
            paths: vec![PathAssignment {
                path: PathId::Nvlink,
                bytes: msg,
                model: nv_model(kind, n),
            }],
            weight: 1.0,
        };
        simulate(&topo, &spec, 500e9).unwrap().total.as_secs_f64()
    }

    #[test]
    fn registry_and_fallback_table() {
        use CollectiveKind::*;
        // Tree registered only where a tree lowering exists.
        assert_eq!(resolve(AllReduce, Algo::Tree, 8), Algo::Tree);
        assert_eq!(resolve(Broadcast, Algo::Tree, 8), Algo::Tree);
        assert_eq!(resolve(AllGather, Algo::Tree, 8), Algo::Ring);
        assert_eq!(resolve(ReduceScatter, Algo::Tree, 8), Algo::Ring);
        // Halving-doubling for the partitionable operators.
        for k in [AllReduce, AllGather, ReduceScatter] {
            assert_eq!(resolve(k, Algo::HalvingDoubling, 8), Algo::HalvingDoubling);
        }
        assert_eq!(resolve(Broadcast, Algo::HalvingDoubling, 8), Algo::Ring);
        assert_eq!(resolve(AllToAll, Algo::Tree, 8), Algo::Ring);
        // Non-power-of-two ranks: everything rings (the single gate).
        for k in [AllReduce, AllGather, ReduceScatter, Broadcast] {
            for a in Algo::ALL {
                assert_eq!(resolve(k, a, 6), Algo::Ring, "{k}/{a} at n=6");
            }
        }
        // Ring always leads the candidate order (tie-break winner).
        for k in [AllReduce, AllGather, ReduceScatter, Broadcast, AllToAll] {
            assert_eq!(candidates(k, 8)[0], Algo::Ring);
        }
    }

    #[test]
    fn analytic_model_orders_the_regimes() {
        let kind = CollectiveKind::AllReduce;
        let m = nv_model(kind, 8);
        let t = |algo, msg| predict(kind, algo, 8, &m, msg, 500e9, PathId::Nvlink);
        // Latency-bound: both alternatives beat ring's 14 steps.
        let small = 256u64 << 10;
        assert!(t(Algo::Tree, small) < t(Algo::Ring, small));
        assert!(t(Algo::HalvingDoubling, small) < t(Algo::Ring, small));
        // Bandwidth-bound: ring's contiguous blocks win.
        let big = 256u64 << 20;
        assert!(t(Algo::Ring, big) < t(Algo::Tree, big));
        assert!(t(Algo::Ring, big) < t(Algo::HalvingDoubling, big));
        assert_eq!(select_analytic(kind, 8, &m, big, 500e9, PathId::Nvlink), Algo::Ring);
        assert_ne!(
            select_analytic(kind, 8, &m, small, 500e9, PathId::Nvlink),
            Algo::Ring
        );
        // n=2 degenerates: ring is optimal at every size (HD pays the
        // strided-segment penalty for the same wire bytes).
        for msg in [small, big] {
            assert_eq!(
                select_analytic(kind, 2, &nv_model(kind, 2), msg, 500e9, PathId::Nvlink),
                Algo::Ring
            );
        }
    }

    #[test]
    fn hd_allreduce_simulates_and_beats_ring_when_latency_bound() {
        let kind = CollectiveKind::AllReduce;
        let small = 256u64 << 10;
        let ring = run_fixed(kind, 8, small, Algo::Ring);
        let hd = run_fixed(kind, 8, small, Algo::HalvingDoubling);
        assert!(hd < ring, "hd {hd:.6}s not under ring {ring:.6}s at 256KiB");
        // And loses the bandwidth-bound regime to the strided penalty.
        let big = 256u64 << 20;
        let ring_b = run_fixed(kind, 8, big, Algo::Ring);
        let hd_b = run_fixed(kind, 8, big, Algo::HalvingDoubling);
        assert!(ring_b < hd_b, "ring {ring_b:.6}s not under hd {hd_b:.6}s at 256MiB");
    }

    #[test]
    fn hd_component_lowerings_simulate() {
        for (kind, msg) in [
            (CollectiveKind::ReduceScatter, 4u64 << 20),
            (CollectiveKind::AllGather, 1u64 << 20),
        ] {
            let ring = run_fixed(kind, 8, msg, Algo::Ring);
            let hd = run_fixed(kind, 8, msg, Algo::HalvingDoubling);
            assert!(ring > 0.0 && hd > 0.0);
            // Latency-bound sizes: fewer stages win despite the penalty.
            assert!(hd < ring, "{kind}: hd {hd:.6}s not under ring {ring:.6}s");
        }
    }

    #[test]
    fn size_classes_bucket_by_pow2() {
        assert_eq!(size_class(1), 0);
        assert_eq!(size_class(256 << 10), size_class(256 << 10));
        assert_ne!(size_class(256 << 10), size_class(512 << 10));
        assert_eq!(size_class((256 << 10) - 1), size_class(256 << 10));
    }

    #[test]
    fn algo_spec_parses_and_displays() {
        assert_eq!("auto".parse::<AlgoSpec>().unwrap(), AlgoSpec::Auto);
        assert_eq!("ring".parse::<AlgoSpec>().unwrap(), AlgoSpec::Fixed(Algo::Ring));
        assert_eq!(
            "halving-doubling".parse::<AlgoSpec>().unwrap(),
            AlgoSpec::Fixed(Algo::HalvingDoubling)
        );
        assert_eq!("hd".parse::<Algo>().unwrap(), Algo::HalvingDoubling);
        assert!("rings".parse::<AlgoSpec>().is_err());
        assert_eq!(AlgoSpec::Auto.to_string(), "auto");
        assert_eq!(AlgoSpec::Fixed(Algo::Tree).to_string(), "tree");
        for a in Algo::ALL {
            assert_eq!(a.to_string().parse::<Algo>().unwrap(), a);
        }
    }

    #[test]
    fn algo_table_probes_switches_and_trusts_ring() {
        let topo = Topology::build(&Preset::H800.spec());
        let mc = MultipathCollective::new(
            &topo,
            Calibration::h800(),
            CollectiveKind::AllReduce,
            8,
        );
        let shares = Shares::nvlink_only();
        let mut table = AlgoTable::new(AlgoSpec::Auto);
        // Bandwidth-bound bucket: analytic ring conclusion, no probes.
        let (big, cost_big) = table.select(&mc, 256 << 20, &shares).unwrap();
        assert_eq!(big, Algo::Ring);
        assert_eq!(cost_big, SimTime::ZERO);
        assert!(table.entry(CollectiveKind::AllReduce, 256 << 20).unwrap().probes.is_empty());
        // Latency-bound bucket: predicted switch, DES-confirmed.
        let (small, cost_small) = table.select(&mc, 256 << 10, &shares).unwrap();
        assert_ne!(small, Algo::Ring);
        assert!(cost_small > SimTime::ZERO);
        // Cached afterwards (200 KiB shares the 256 KiB pow2 bucket):
        // same answer, no new probe time.
        let (again, cost_again) = table.select(&mc, 200 << 10, &shares).unwrap();
        assert_eq!(again, small);
        assert_eq!(cost_again, SimTime::ZERO);
        assert_eq!(table.chosen(CollectiveKind::AllReduce, 256 << 10), Some(small));
        // Fixed specs never probe.
        let mut fixed = AlgoTable::new(AlgoSpec::Fixed(Algo::Tree));
        let (a, c) = fixed.select(&mc, 256 << 10, &shares).unwrap();
        assert_eq!(a, Algo::Tree);
        assert_eq!(c, SimTime::ZERO);
    }

    #[test]
    fn degraded_mode_duty_and_factor() {
        let dm = DegradedMode::one_stripe_down(8, 0.05, 0.5);
        assert!((dm.duty - 0.5 / 0.55).abs() < 1e-12);
        assert!((dm.factor - 0.875).abs() < 1e-12);
        // A single lane can't lose "one of its stripes" fractionally —
        // one lane down is an outage, priced by the recovery policies.
        assert_eq!(DegradedMode::one_stripe_down(1, 0.05, 0.5).factor, 1.0);
        // MTTR = 0 means no degraded duty at all.
        assert_eq!(DegradedMode::one_stripe_down(8, 0.05, 0.0).duty, 0.0);
    }

    #[test]
    fn predict_degraded_is_the_duty_weighted_mixture() {
        let kind = CollectiveKind::AllReduce;
        let m = nv_model(kind, 8);
        let dm = DegradedMode::one_stripe_down(8, 0.05, 0.5);
        for msg in [256u64 << 10, 16 << 20, 256 << 20] {
            let peak = predict(kind, Algo::Ring, 8, &m, msg, 500e9, PathId::Nvlink);
            let mut weak = m;
            weak.rate_cap = m.rate_cap * dm.factor;
            let slow = predict(kind, Algo::Ring, 8, &weak, msg, 500e9, PathId::Nvlink);
            let expect = (1.0 - dm.duty) * peak.as_secs_f64() + dm.duty * slow.as_secs_f64();
            let got =
                predict_degraded(kind, Algo::Ring, 8, &m, msg, 500e9, PathId::Nvlink, &dm);
            assert!(
                (got.as_secs_f64() - expect).abs() < 1e-12,
                "mixture mismatch at {msg}B: {got:?} vs {expect}"
            );
            assert!(got > peak, "degradation must cost time at {msg}B");
        }
        // Zero duty collapses to the peak prediction exactly.
        let none = DegradedMode { duty: 0.0, factor: 0.875 };
        let msg = 4u64 << 20;
        assert_eq!(
            predict_degraded(kind, Algo::Ring, 8, &m, msg, 500e9, PathId::Nvlink, &none),
            predict(kind, Algo::Ring, 8, &m, msg, 500e9, PathId::Nvlink)
        );
    }

    #[test]
    fn degradation_shifts_the_crossover_toward_ring() {
        // Degradation inflates every candidate's bandwidth term by the
        // same (1-duty) + duty/factor multiplier, so low-bandwidth-
        // coefficient candidates (ring) win buckets they lost at peak:
        // somewhere in the latency/bandwidth transition there must be a
        // size where the peak ranking leaves ring but the duty-weighted
        // ranking keeps it.
        let kind = CollectiveKind::AllReduce;
        let m = nv_model(kind, 8);
        let dm = DegradedMode { duty: 0.9, factor: 0.5 };
        let best = |msg: u64, dm: Option<&DegradedMode>| {
            candidates(kind, 8)
                .iter()
                .map(|&a| {
                    let t = match dm {
                        Some(d) => {
                            predict_degraded(kind, a, 8, &m, msg, 500e9, PathId::Nvlink, d)
                        }
                        None => predict(kind, a, 8, &m, msg, 500e9, PathId::Nvlink),
                    };
                    (a, t)
                })
                .min_by(|x, y| x.1.cmp(&y.1))
                .unwrap()
                .0
        };
        let mut shifted = false;
        let mut msg = 64u64 << 10;
        while msg <= 256 << 20 {
            let at_peak = best(msg, None);
            let at_degraded = best(msg, Some(&dm));
            // Degradation never moves a bucket *away* from ring.
            if at_peak == Algo::Ring {
                assert_eq!(at_degraded, Algo::Ring, "regression at {msg}B");
            }
            if at_peak != Algo::Ring && at_degraded == Algo::Ring {
                shifted = true;
            }
            msg <<= 1;
        }
        assert!(shifted, "no bucket shifted toward ring under degradation");
    }

    #[test]
    fn degraded_table_decides_analytically_and_resets_cache() {
        let topo = Topology::build(&Preset::H800.spec());
        let mc = MultipathCollective::new(
            &topo,
            Calibration::h800(),
            CollectiveKind::AllReduce,
            8,
        );
        let shares = Shares::nvlink_only();
        let mut table = AlgoTable::new(AlgoSpec::Auto);
        // Seed a cached entry, then switch on degraded mode: the cache
        // must be dropped (peak-ranked picks are stale under MTBF).
        table.select(&mc, 256 << 10, &shares).unwrap();
        assert!(table.entry(CollectiveKind::AllReduce, 256 << 10).is_some());
        let dm = DegradedMode::one_stripe_down(8, 0.05, 0.5);
        let mut table = table.with_degraded_mode(dm);
        assert_eq!(table.degraded_mode(), Some(dm));
        assert!(table.entry(CollectiveKind::AllReduce, 256 << 10).is_none());
        // Degraded mode never probes: the DES measures the healthy
        // fabric, which is exactly what MTBF-aware tuning must not
        // trust alone.
        let (_, cost) = table.select(&mc, 256 << 10, &shares).unwrap();
        assert_eq!(cost, SimTime::ZERO);
        let e = table.entry(CollectiveKind::AllReduce, 256 << 10).unwrap();
        assert!(e.probes.is_empty());
    }
}
