//! Binomial-tree lowerings — the paper's §6 alternative for the 8-GPU
//! latency problem: "we will explore alternatives like tree-based
//! algorithms".
//!
//! * [`build_allreduce`] — binomial tree rooted at rank 0: a reduce sweep
//!   up (log₂N stages; each stage, half of the remaining ranks sends its
//!   full vector to its partner, who combines) followed by a broadcast
//!   sweep down. Versus the ring's 2(N−1) sequential steps this pays only
//!   2·log₂N step latencies — but the root's single lane carries log₂N
//!   full vectors each way, so the bandwidth term is ≈log₂N·S/B instead
//!   of ring's 2·S·(N−1)/(N·B): tree wins small (latency-bound)
//!   messages, ring wins large ones.
//! * [`build_broadcast`] — binomial fan-out from rank 0: log₂N stages
//!   versus the chain's N−1 hops, at the price of the root streaming
//!   log₂N full copies.
//!
//! Both are registered in the [`super::algo`] lowering registry (which
//! falls back to ring for non-power-of-two rank counts) and swept against
//! ring by the `repro ablation` subcommand — the measured crossover table
//! lives in EXPERIMENTS.md §Algorithms.

use super::schedule::{GraphBuilder, SimOutcome};
use crate::links::{PathId, PathModel};
use crate::sim::{Engine, SimTime, TaskId};
use crate::topology::Topology;
use anyhow::Result;

/// Append tree-AllReduce tasks for a `msg`-byte vector on `path`.
/// Requires power-of-two rank counts (the paper's 2/4/8) — callers going
/// through [`super::algo::lower`] get the ring fallback instead.
pub fn build_allreduce(b: &mut GraphBuilder<'_>, path: PathId, msg: u64, tag: u32) {
    let n = b.n;
    assert!(n.is_power_of_two(), "tree schedule needs power-of-two ranks");
    let stages = n.trailing_zeros() as usize;

    // arrivals[r]: per-chunk task ids for the data most recently landed
    // (and reduced) at rank r.
    let mut arrivals: Vec<Vec<TaskId>> = vec![Vec::new(); n];

    // ---- Reduce sweep (leaves → root 0) ----
    for s in 0..stages {
        let span = 1usize << s; // senders are at odd multiples of span
        for r in (0..n).step_by(2 * span) {
            let sender = r + span;
            // Sender forwards its (already locally-reduced) vector.
            let deps: Vec<Vec<TaskId>> = arrivals[sender].iter().map(|t| vec![*t]).collect();
            let a = b.send_block(path, sender, r, msg, &deps, true, true, tag);
            // Receiver must also have finished ITS previous-stage reduce
            // before the combined result is final — join chunk-wise.
            let merged: Vec<TaskId> = if arrivals[r].is_empty() {
                a
            } else {
                a.iter()
                    .zip(arrivals[r].iter())
                    .map(|(x, y)| b.graph.barrier(vec![*x, *y]))
                    .collect()
            };
            arrivals[r] = merged;
        }
    }

    // ---- Broadcast sweep (root 0 → leaves), reverse stage order ----
    for s in (0..stages).rev() {
        let span = 1usize << s;
        for r in (0..n).step_by(2 * span) {
            let receiver = r + span;
            let deps: Vec<Vec<TaskId>> = arrivals[r].iter().map(|t| vec![*t]).collect();
            let a = b.send_block(path, r, receiver, msg, &deps, true, false, tag);
            arrivals[receiver] = a;
        }
    }
}

/// Append binomial-tree Broadcast tasks for `msg` bytes from rank 0 on
/// `path`: stage k (spans N/2, N/4, …, 1) has every holder forward the
/// full vector to the rank `span` above it. Chunk-wise dependency
/// threading lets a subtree start forwarding the moment a chunk lands.
/// `entry` gates the root's sends (hierarchical phases pass the previous
/// phase's producers; flat callers pass `&[]` for resident data).
/// Returns per-rank arrival chunk ids (rank 0, the source, stays empty) —
/// the same shape as the chain lowering, so hierarchical callers build
/// their availability maps identically.
pub fn build_broadcast(
    b: &mut GraphBuilder<'_>,
    path: PathId,
    msg: u64,
    entry: &[TaskId],
    tag: u32,
) -> Vec<Vec<TaskId>> {
    let n = b.n;
    assert!(n.is_power_of_two(), "tree schedule needs power-of-two ranks");
    let stages = n.trailing_zeros() as usize;
    let n_chunks = b.chunks_for(path, msg).len();
    let mut at: Vec<Vec<TaskId>> = vec![Vec::new(); n];
    for s in (0..stages).rev() {
        let span = 1usize << s;
        for r in (0..n).step_by(2 * span) {
            let dst = r + span;
            let deps: Vec<Vec<TaskId>> = if at[r].is_empty() {
                // Root-resident data, gated on the caller's entry deps.
                vec![entry.to_vec(); n_chunks]
            } else {
                at[r].iter().map(|t| vec![*t]).collect()
            };
            at[dst] = b.send_block(path, r, dst, msg, &deps, true, false, tag);
        }
    }
    at
}

/// Simulate a single-path tree AllReduce in isolation — the ablations
/// bench's measurable. (The `repro ablation` CLI sweep goes through the
/// registry instead: `bench_harness::ablation_sweep` →
/// `MultipathCollective::run_algo`.)
pub fn simulate_tree(
    topo: &Topology,
    model: PathModel,
    path: PathId,
    n: usize,
    msg: u64,
    reduce_bps: f64,
) -> Result<SimOutcome> {
    let mut b = GraphBuilder::new(topo, n, &[(path, model)], reduce_bps);
    build_allreduce(&mut b, path, msg, path.tag());
    let tasks = b.graph.len();
    let sched = Engine::new(&b.pool).run(&b.graph)?;
    Ok(SimOutcome {
        total: sched.makespan,
        per_path: vec![crate::collectives::schedule::PathTiming {
            path,
            bytes: msg,
            time: sched.makespan,
        }],
        events: sched.events,
        tasks,
    })
}

/// Latency floor of the tree AllReduce (for quick analytical checks):
/// 2·log₂N stages, each paying the per-step α plus one full-vector
/// transfer at the path's rate cap.
pub fn latency_floor(n: usize, model: &PathModel, msg: u64) -> SimTime {
    let stages = n.trailing_zeros() as u64;
    let per_stage = model.step_latency + SimTime::for_transfer(msg, model.rate_cap);
    SimTime::from_nanos(2 * stages * per_stage.as_nanos())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balancer::Shares;
    use crate::collectives::multipath::MultipathCollective;
    use crate::collectives::CollectiveKind;
    use crate::config::presets::Preset;
    use crate::links::calib::Calibration;

    fn setup() -> (Topology, Calibration) {
        (Topology::build(&Preset::H800.spec()), Calibration::h800())
    }

    fn ring_ar_time(topo: &Topology, calib: &Calibration, n: usize, msg: u64) -> f64 {
        MultipathCollective::new(topo, calib.clone(), CollectiveKind::AllReduce, n)
            .run(msg, &Shares::nvlink_only())
            .unwrap()
            .total()
            .as_secs_f64()
    }

    fn tree_ar_time(topo: &Topology, calib: &Calibration, n: usize, msg: u64) -> f64 {
        let model = calib.nvlink_model(CollectiveKind::AllReduce, n, topo.spec.nvlink_unidir_bps());
        simulate_tree(topo, model, PathId::Nvlink, n, msg, calib.reduce_bps)
            .unwrap()
            .total
            .as_secs_f64()
    }

    /// §6's motivation: at 8 GPUs and small messages, tree (2·log₂8 = 6
    /// latency hops) beats ring (14 steps).
    #[test]
    fn tree_wins_latency_bound_regime() {
        let (topo, calib) = setup();
        let msg = 256 << 10; // 256 KB
        let ring = ring_ar_time(&topo, &calib, 8, msg);
        let tree = tree_ar_time(&topo, &calib, 8, msg);
        assert!(
            tree < ring,
            "tree {tree:.6}s should beat ring {ring:.6}s at 256KB"
        );
    }

    /// And the flip side: at 256 MB ring's bandwidth optimality wins.
    #[test]
    fn ring_wins_bandwidth_bound_regime() {
        let (topo, calib) = setup();
        let msg = 256 << 20;
        let ring = ring_ar_time(&topo, &calib, 8, msg);
        let tree = tree_ar_time(&topo, &calib, 8, msg);
        assert!(
            ring < tree,
            "ring {ring:.6}s should beat tree {tree:.6}s at 256MB"
        );
    }

    /// Tree schedules only exist for power-of-two rank counts (the
    /// registry falls back to ring; the builder itself refuses).
    #[test]
    #[should_panic(expected = "power-of-two")]
    fn non_pow2_rejected() {
        let (topo, calib) = setup();
        let model =
            calib.nvlink_model(CollectiveKind::AllReduce, 8, topo.spec.nvlink_unidir_bps());
        let mut b = GraphBuilder::new(&topo, 6, &[(PathId::Nvlink, model)], calib.reduce_bps);
        build_allreduce(&mut b, PathId::Nvlink, 1 << 20, 1);
    }

    /// 2-rank tree degenerates to one exchange + one return — both
    /// schedules must then be within a small factor.
    #[test]
    fn two_rank_degenerate_case() {
        let (topo, calib) = setup();
        let msg = 32 << 20;
        let ring = ring_ar_time(&topo, &calib, 2, msg);
        let tree = tree_ar_time(&topo, &calib, 2, msg);
        let ratio = tree / ring;
        assert!(
            (0.5..=2.0).contains(&ratio),
            "2-rank tree/ring ratio {ratio:.2} out of range"
        );
    }

    fn bcast_time(n: usize, msg: u64, tree: bool) -> f64 {
        let (topo, calib) = setup();
        let kind = CollectiveKind::Broadcast;
        let model = calib.nvlink_model(kind, n, topo.spec.nvlink_unidir_bps());
        let mut b = GraphBuilder::new(&topo, n, &[(PathId::Nvlink, model)], calib.reduce_bps);
        if tree {
            build_broadcast(&mut b, PathId::Nvlink, msg, &[], 1);
        } else {
            crate::collectives::broadcast::build_tasks(&mut b, PathId::Nvlink, msg, 1);
        }
        Engine::new(&b.pool)
            .run(&b.graph)
            .unwrap()
            .makespan
            .as_secs_f64()
    }

    /// Binomial broadcast: log₂N launch latencies beat the chain's N−1
    /// for small messages; the chain's single-copy streaming wins large.
    #[test]
    fn binomial_broadcast_crossover() {
        let small = 64u64 << 10;
        assert!(
            bcast_time(8, small, true) < bcast_time(8, small, false),
            "binomial should beat chain at 64KiB"
        );
        let big = 256u64 << 20;
        assert!(
            bcast_time(8, big, false) < bcast_time(8, big, true),
            "chain should beat binomial at 256MiB"
        );
    }
}
