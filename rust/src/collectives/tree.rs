//! Tree AllReduce — the paper's §6 alternative for the 8-GPU latency
//! problem: "we will explore alternatives like tree-based algorithms".
//!
//! Binomial tree, rooted at rank 0: a reduce sweep up (log₂N stages, each
//! half of the remaining ranks sends its full vector to its partner, who
//! combines) followed by a broadcast sweep down. Versus the ring's
//! 2(N−1) sequential steps this pays only 2·log₂N step latencies — but
//! each non-leaf link carries the *whole* message, so the bandwidth term
//! is ≈2·S/B instead of ring's 2·S·(N−1)/(N·B): tree wins small
//! (latency-bound) messages, ring wins large ones. The ablation bench
//! sweeps the crossover.

use super::ring::chunk_sizes;
use super::schedule::{GraphBuilder, SimOutcome};
use crate::links::{PathId, PathModel};
use crate::sim::{Engine, SimTime, TaskId};
use crate::topology::Topology;
use anyhow::Result;

/// Append tree-AllReduce tasks for a `msg`-byte vector on `path`.
/// Requires power-of-two rank counts (the paper's 2/4/8).
pub fn build_tasks(b: &mut GraphBuilder<'_>, path: PathId, msg: u64, tag: u32) {
    let n = b.n;
    assert!(n.is_power_of_two(), "tree schedule needs power-of-two ranks");
    let stages = n.trailing_zeros() as usize;

    // arrivals[r]: per-chunk task ids for the data most recently landed
    // (and reduced) at rank r.
    let mut arrivals: Vec<Vec<TaskId>> = vec![Vec::new(); n];

    // ---- Reduce sweep (leaves → root 0) ----
    for s in 0..stages {
        let span = 1usize << s; // senders are at odd multiples of span
        for r in (0..n).step_by(2 * span) {
            let sender = r + span;
            // Sender forwards its (already locally-reduced) vector.
            let deps: Vec<Vec<TaskId>> = arrivals[sender].iter().map(|t| vec![*t]).collect();
            let a = b.send_block(path, sender, r, msg, &deps, true, true, tag);
            // Receiver must also have finished ITS previous-stage reduce
            // before the combined result is final — join chunk-wise.
            let merged: Vec<TaskId> = if arrivals[r].is_empty() {
                a
            } else {
                a.iter()
                    .zip(arrivals[r].iter())
                    .map(|(x, y)| b.graph.barrier(vec![*x, *y]))
                    .collect()
            };
            arrivals[r] = merged;
        }
    }

    // ---- Broadcast sweep (root 0 → leaves), reverse stage order ----
    for s in (0..stages).rev() {
        let span = 1usize << s;
        for r in (0..n).step_by(2 * span) {
            let receiver = r + span;
            let deps: Vec<Vec<TaskId>> = arrivals[r].iter().map(|t| vec![*t]).collect();
            let a = b.send_block(path, r, receiver, msg, &deps, true, false, tag);
            arrivals[receiver] = a;
        }
    }
}

/// Simulate a single-path tree AllReduce (the ablation's entry point).
pub fn simulate_tree(
    topo: &Topology,
    model: PathModel,
    path: PathId,
    n: usize,
    msg: u64,
    reduce_bps: f64,
) -> Result<SimOutcome> {
    let mut b = GraphBuilder::new(topo, n, &[(path, model)], reduce_bps);
    build_tasks(&mut b, path, msg, path.tag());
    let tasks = b.graph.len();
    let sched = Engine::new(&b.pool).run(&b.graph)?;
    Ok(SimOutcome {
        total: sched.makespan,
        per_path: vec![crate::collectives::schedule::PathTiming {
            path,
            bytes: msg,
            time: sched.makespan,
        }],
        events: sched.events,
        tasks,
    })
}

/// Latency floor of the tree schedule (for quick analytical checks).
pub fn latency_floor(n: usize, model: &PathModel, msg: u64) -> SimTime {
    let stages = n.trailing_zeros() as u64;
    let per_stage = model.step_latency + SimTime::for_transfer(msg, model.rate_cap);
    let chunks = chunk_sizes(msg, model.chunk_bytes).len();
    let _ = chunks;
    SimTime::from_nanos(2 * stages * per_stage.as_nanos())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balancer::Shares;
    use crate::collectives::multipath::MultipathCollective;
    use crate::collectives::CollectiveKind;
    use crate::config::presets::Preset;
    use crate::links::calib::Calibration;

    fn setup() -> (Topology, Calibration) {
        (Topology::build(&Preset::H800.spec()), Calibration::h800())
    }

    fn ring_ar_time(topo: &Topology, calib: &Calibration, n: usize, msg: u64) -> f64 {
        MultipathCollective::new(topo, calib.clone(), CollectiveKind::AllReduce, n)
            .run(msg, &Shares::nvlink_only())
            .unwrap()
            .total()
            .as_secs_f64()
    }

    fn tree_ar_time(topo: &Topology, calib: &Calibration, n: usize, msg: u64) -> f64 {
        let model = calib.nvlink_model(CollectiveKind::AllReduce, n, topo.spec.nvlink_unidir_bps());
        simulate_tree(topo, model, PathId::Nvlink, n, msg, calib.reduce_bps)
            .unwrap()
            .total
            .as_secs_f64()
    }

    /// §6's motivation: at 8 GPUs and small messages, tree (2·log₂8 = 6
    /// latency hops) beats ring (14 steps).
    #[test]
    fn tree_wins_latency_bound_regime() {
        let (topo, calib) = setup();
        let msg = 256 << 10; // 256 KB
        let ring = ring_ar_time(&topo, &calib, 8, msg);
        let tree = tree_ar_time(&topo, &calib, 8, msg);
        assert!(
            tree < ring,
            "tree {tree:.6}s should beat ring {ring:.6}s at 256KB"
        );
    }

    /// And the flip side: at 256 MB ring's bandwidth optimality wins.
    #[test]
    fn ring_wins_bandwidth_bound_regime() {
        let (topo, calib) = setup();
        let msg = 256 << 20;
        let ring = ring_ar_time(&topo, &calib, 8, msg);
        let tree = tree_ar_time(&topo, &calib, 8, msg);
        assert!(
            ring < tree,
            "ring {ring:.6}s should beat tree {tree:.6}s at 256MB"
        );
    }

    /// Tree schedules only exist for power-of-two rank counts.
    #[test]
    #[should_panic(expected = "power-of-two")]
    fn non_pow2_rejected() {
        let (topo, calib) = setup();
        let model =
            calib.nvlink_model(CollectiveKind::AllReduce, 8, topo.spec.nvlink_unidir_bps());
        let mut b = GraphBuilder::new(&topo, 6, &[(PathId::Nvlink, model)], calib.reduce_bps);
        build_tasks(&mut b, PathId::Nvlink, 1 << 20, 1);
    }

    /// 2-rank tree degenerates to one exchange + one return — both
    /// schedules must then be within a small factor.
    #[test]
    fn two_rank_degenerate_case() {
        let (topo, calib) = setup();
        let msg = 32 << 20;
        let ring = ring_ar_time(&topo, &calib, 2, msg);
        let tree = tree_ar_time(&topo, &calib, 2, msg);
        let ratio = tree / ring;
        assert!(
            (0.5..=2.0).contains(&ratio),
            "2-rank tree/ring ratio {ratio:.2} out of range"
        );
    }
}
