//! Ring AllReduce — timing-graph construction.
//!
//! ReduceScatter (N−1 steps, blocks of S/N, consumer combines each
//! arrival) followed by AllGather (N−1 steps) — the 2(N−1) sequential
//! steps whose latency amplification explains the paper's 8-GPU AllReduce
//! result (§5.3): at N=8 every per-step α is paid 14×, on blocks of only
//! S/8, so slow-path offloading stops paying.

use super::ring;
use super::schedule::GraphBuilder;
use crate::links::PathId;
use crate::sim::TaskId;

/// Append the AllReduce tasks for a `msg`-byte vector on `path`.
///
/// Timing uses uniform blocks of `ceil(msg/n)` (the ≤1-chunk remainder
/// imbalance is below the model's fidelity; the functional executor
/// handles exact extents).
pub fn build_tasks(b: &mut GraphBuilder<'_>, path: PathId, msg: u64, tag: u32) {
    let n = b.n;
    let block = msg.div_ceil(n as u64);

    // ---- Phase 1: ReduceScatter ----
    // rs_done[r][c]: chunk c of the block rank r finished *receiving and
    // reducing* at the final RS step it participates in, indexed by step.
    let mut prev_arrivals: Vec<Vec<TaskId>> = vec![Vec::new(); n];
    for s in 0..n - 1 {
        let mut arrivals: Vec<Vec<TaskId>> = Vec::with_capacity(n);
        for r in 0..n {
            let deps: Vec<Vec<TaskId>> = if s == 0 {
                Vec::new()
            } else {
                prev_arrivals[ring::prev(r, n)]
                    .iter()
                    .map(|t| vec![*t])
                    .collect()
            };
            // reduce_after: the staged-path consumer combines out of the
            // pinned buffer before it can forward (charged on PCIe only;
            // NVLink's in-fabric reduce is inside its fitted B_eff).
            let a = b.send_block(path, r, ring::next(r, n), block, &deps, true, true, tag);
            arrivals.push(a);
        }
        prev_arrivals = arrivals;
    }

    // ---- Phase 2: AllGather of the reduced blocks ----
    // Rank r starts by sending the block it finished reducing, which
    // arrived via the last RS step (prev_arrivals[prev(r)] — the arrival
    // *at r* is indexed by the receiving rank r).
    let mut prev_ag: Vec<Vec<TaskId>> = (0..n)
        .map(|r| prev_arrivals[r].clone())
        .collect();
    for _s in 0..n - 1 {
        let mut arrivals: Vec<Vec<TaskId>> = Vec::with_capacity(n);
        for r in 0..n {
            // Data to forward lives at r: first AG step depends on r's own
            // final RS arrival; later steps on the AG arrival at r (which
            // came from prev(r)'s send last step).
            let d: Vec<Vec<TaskId>> = prev_ag[r].iter().map(|t| vec![*t]).collect();
            let a = b.send_block(path, r, ring::next(r, n), block, &d, true, false, tag);
            arrivals.push(a);
        }
        // Next step r forwards what it received: arrival at r was sent by
        // prev(r); reindex so prev_ag[r] is "data now at r".
        prev_ag = (0..n)
            .map(|r| arrivals[ring::prev(r, n)].clone())
            .collect();
    }
}

#[cfg(test)]
mod tests {
    use crate::collectives::algo::Algo;
    use crate::collectives::schedule::{simulate, MultipathSpec, PathAssignment};
    use crate::collectives::CollectiveKind;
    use crate::config::presets::Preset;
    use crate::links::calib::Calibration;
    use crate::links::PathId;
    use crate::topology::Topology;

    fn run(n: usize, mib: u64) -> f64 {
        let topo = Topology::build(&Preset::H800.spec());
        let kind = CollectiveKind::AllReduce;
        let model =
            Calibration::h800().nvlink_model(kind, n, topo.spec.nvlink_unidir_bps());
        let s = mib << 20;
        let spec = MultipathSpec {
            kind,
            n,
            msg_bytes: s,
            algo: Algo::Ring,
            paths: vec![PathAssignment {
                path: PathId::Nvlink,
                bytes: s,
                model,
            }],
            weight: 1.0,
        };
        let out = simulate(&topo, &spec, 60e9).unwrap();
        kind.algbw_gbps(s, out.total.as_secs_f64())
    }

    /// NVLink-only DES vs the paper's NCCL AllReduce column (Table 2).
    #[test]
    fn matches_paper_nccl_column() {
        let cases = [
            (2, 32, 112.0),
            (2, 128, 132.0),
            (2, 256, 139.0),
            (4, 64, 90.0),
            (4, 256, 98.0),
            (8, 256, 107.0),
        ];
        for (n, mib, paper) in cases {
            let got = run(n, mib);
            let err = (got - paper).abs() / paper;
            assert!(
                err < 0.10,
                "AR n={n} {mib}MB: sim {got:.1} GB/s vs paper {paper} ({:.0}% off)",
                err * 100.0
            );
        }
    }

    /// AllReduce walks the ring twice: with latency amortized away, its
    /// algbw must approach B_eff·N/(2(N−1)) — below AllGather's
    /// per-contribution rate at equal B.
    #[test]
    fn two_phase_cost_structure() {
        let got = run(8, 256);
        // B_eff = 196 GB/s, N=8 → bound = 196·8/14 = 112.
        assert!(got < 112.0, "AR algbw {got:.1} exceeds ring bound");
        assert!(got > 95.0, "AR algbw {got:.1} implausibly low");
    }
}
