//! Collective operations over multiple heterogeneous paths.
//!
//! Each collective has two faces kept in lockstep:
//!
//! * a **timing** face — [`schedule`] compiles the ring schedule of every
//!   active path into one [`crate::sim::TaskGraph`] (so cross-path
//!   contention is modelled) and runs it on the DES, yielding per-path
//!   completion times for the balancer and the reported bandwidth;
//! * a **functional** face — [`exec`] runs the same ring schedule with
//!   real threads moving real bytes through [`crate::memory`] staging
//!   channels under the §3.1 counter-semaphore protocol, making the
//!   paper's "lossless" claim bit-checkable.
//!
//! Supported operators: AllReduce and AllGather (the paper's evaluation,
//! §5.1) plus ReduceScatter, Broadcast and AllToAll (its §6 future work).
//!
//! Multi-node clusters lower through [`hierarchical`]: intra-node phase →
//! NIC-striped inter-node phase → intra-node phase, compiled into one
//! task graph over the cluster's shared resource pool; `n_nodes = 1`
//! degenerates to the flat single-node pipeline above bit-identically.
//!
//! The *lowering algorithm* is a tuned dimension of its own ([`algo`]):
//! ring is the bandwidth-optimal default, binomial [`tree`] and
//! halving-doubling lowerings open the latency-bound small-message
//! regime (§5.3/§6), and an [`algo::AlgoTable`] tuner picks per
//! (operator, message-size-bucket) — orthogonal to the balancer's
//! path-share dimension.

pub mod algo;
pub mod allgather;
pub mod allreduce;
pub mod alltoall;
pub mod broadcast;
pub mod exec;
pub mod hierarchical;
pub mod multipath;
pub mod reduce_scatter;
pub mod ring;
pub mod schedule;
pub mod tree;

use std::fmt;
use std::str::FromStr;

/// Which collective operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollectiveKind {
    AllReduce,
    AllGather,
    ReduceScatter,
    Broadcast,
    AllToAll,
}

impl CollectiveKind {
    /// Sequential ring steps — the latency amplification factor of §5.3
    /// ("A Ring AllReduce requires 2(N−1) sequential steps, which is
    /// double the N−1 steps of AllGather").
    pub fn ring_steps(self, n: usize) -> usize {
        match self {
            CollectiveKind::AllReduce => 2 * (n - 1),
            CollectiveKind::AllGather
            | CollectiveKind::ReduceScatter
            | CollectiveKind::Broadcast => n - 1,
            CollectiveKind::AllToAll => n - 1,
        }
    }

    /// Bytes each GPU puts on the wire for a message of `msg` bytes
    /// (paper convention: for AllGather/AllToAll `msg` is the per-rank
    /// contribution; for AllReduce it is the full vector length).
    pub fn wire_bytes_per_gpu(self, msg: u64, n: usize) -> u64 {
        let n64 = n as u64;
        match self {
            // RS: (n-1) chunks of msg/n, then AG: (n-1) chunks of msg/n.
            CollectiveKind::AllReduce => 2 * (n64 - 1) * (msg / n64),
            // Forward every block except your own once.
            CollectiveKind::AllGather => (n64 - 1) * msg,
            CollectiveKind::ReduceScatter => (n64 - 1) * (msg / n64),
            CollectiveKind::Broadcast => msg,
            // Send a distinct msg/n block to each peer (ring-routed).
            CollectiveKind::AllToAll => (n64 - 1) * (msg / n64),
        }
    }

    /// Paper metric: algorithm bandwidth = message size / completion time
    /// (the nccl-tests convention the paper reports, §5.2).
    pub fn algbw_gbps(self, msg_bytes: u64, seconds: f64) -> f64 {
        debug_assert!(seconds > 0.0);
        msg_bytes as f64 / seconds / 1e9
    }
}

impl FromStr for CollectiveKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s.to_ascii_lowercase().replace('-', "_").as_str() {
            "allreduce" | "all_reduce" => CollectiveKind::AllReduce,
            "allgather" | "all_gather" => CollectiveKind::AllGather,
            "reduce_scatter" | "reducescatter" => CollectiveKind::ReduceScatter,
            "broadcast" | "bcast" => CollectiveKind::Broadcast,
            "alltoall" | "all_to_all" => CollectiveKind::AllToAll,
            other => anyhow::bail!("unknown collective '{other}'"),
        })
    }
}

impl fmt::Display for CollectiveKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            CollectiveKind::AllReduce => "allreduce",
            CollectiveKind::AllGather => "allgather",
            CollectiveKind::ReduceScatter => "reduce_scatter",
            CollectiveKind::Broadcast => "broadcast",
            CollectiveKind::AllToAll => "alltoall",
        };
        write!(f, "{s}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_counts_match_paper() {
        assert_eq!(CollectiveKind::AllReduce.ring_steps(8), 14);
        assert_eq!(CollectiveKind::AllGather.ring_steps(8), 7);
        assert_eq!(CollectiveKind::AllReduce.ring_steps(2), 2);
    }

    #[test]
    fn wire_bytes() {
        // AR on 8 GPUs: 2·7·(S/8) = 1.75·S per GPU.
        assert_eq!(
            CollectiveKind::AllReduce.wire_bytes_per_gpu(800, 8),
            2 * 7 * 100
        );
        // AG on 4 GPUs: 3·S.
        assert_eq!(CollectiveKind::AllGather.wire_bytes_per_gpu(100, 4), 300);
    }

    #[test]
    fn algbw_definition() {
        // 256 MB in 2 ms → 128 GB/s, independent of operator.
        let bw = CollectiveKind::AllReduce.algbw_gbps(256 * (1 << 20), 256.0 * (1 << 20) as f64 / 128e9);
        assert!((bw - 128.0).abs() < 1e-9);
    }
}
