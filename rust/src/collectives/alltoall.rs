//! AllToAll — timing-graph construction (§6 future work: "we plan to
//! extend FlexLink to support a broader range of communication
//! primitives, such as AllToAll").
//!
//! Switch-based fabrics allow direct pairwise exchange; each rank sends
//! its n−1 distinct S/n blocks one offset at a time (egress-serialized,
//! per-offset α), which matches how an NVSHMEM put-based AllToAll paces
//! its doorbells.

use super::schedule::GraphBuilder;
use crate::links::PathId;
use crate::sim::TaskId;

/// Append AllToAll tasks for per-rank contribution `msg` on `path`
/// (each peer receives `msg/n`).
pub fn build_tasks(b: &mut GraphBuilder<'_>, path: PathId, msg: u64, tag: u32) {
    let n = b.n;
    let block = msg.div_ceil(n as u64);
    let mut prev_send: Vec<Vec<TaskId>> = vec![Vec::new(); n];
    for offset in 1..n {
        let mut sends: Vec<Vec<TaskId>> = Vec::with_capacity(n);
        for r in 0..n {
            let dst = (r + offset) % n;
            let deps: Vec<Vec<TaskId>> = prev_send[r].iter().map(|t| vec![*t]).collect();
            let a = b.send_block(path, r, dst, block, &deps, true, false, tag);
            sends.push(a);
        }
        prev_send = sends;
    }
}

#[cfg(test)]
mod tests {
    use crate::collectives::algo::Algo;
    use crate::collectives::schedule::{simulate, MultipathSpec, PathAssignment};
    use crate::collectives::CollectiveKind;
    use crate::config::presets::Preset;
    use crate::links::calib::Calibration;
    use crate::links::PathId;
    use crate::topology::Topology;

    /// Total wire bytes per GPU for AllToAll ≈ AllGather's per-rank-S
    /// scaled by 1/n — so at equal message size AllToAll completes much
    /// faster than AllGather on the same path.
    #[test]
    fn cheaper_than_allgather_at_same_message() {
        let topo = Topology::build(&Preset::H800.spec());
        let calib = Calibration::h800();
        let s = 256u64 << 20;
        let mut t = Vec::new();
        for kind in [CollectiveKind::AllToAll, CollectiveKind::AllGather] {
            let model = calib.nvlink_model(kind, 8, topo.spec.nvlink_unidir_bps());
            let spec = MultipathSpec {
                kind,
                n: 8,
                msg_bytes: s,
                algo: Algo::Ring,
                paths: vec![PathAssignment {
                    path: PathId::Nvlink,
                    bytes: s,
                    model,
                }],
                weight: 1.0,
            };
            t.push(simulate(&topo, &spec, 60e9).unwrap().total.as_secs_f64());
        }
        assert!(
            t[0] < t[1] / 3.0,
            "alltoall {:.4}s should be ≪ allgather {:.4}s",
            t[0],
            t[1]
        );
    }
}
