//! Timing-graph compilation for multi-path collectives.
//!
//! [`GraphBuilder`] clones the node's raw resource pool and adds, per
//! (path, GPU, direction), a *protocol resource* whose capacity is the
//! path's calibrated effective rate. Chunk flows route through both their
//! protocol resource and the raw physical links, so
//!
//! * a path never exceeds its single-stream protocol efficiency (§2.2.3 —
//!   and extra parallel streams on one path gain nothing, reproducing the
//!   CUDA-driver serialization observation), and
//! * different paths still contend for the *shared physical lane*
//!   (GPU→NIC and GPU→host both crossing `pcie.up[g]`, §2.2.2).
//!
//! [`simulate`] executes one multi-path collective and reports per-path
//! completion times — the observable the two-stage balancer consumes.

use super::algo::{self, Algo};
use super::ring::chunk_sizes;
use super::CollectiveKind;
use crate::links::{PathId, PathModel};
use crate::sim::{
    Engine, ResourceId, ResourcePool, Schedule, SimTime, TaskGraph, TaskId, TaskKind,
};
use crate::topology::Topology;
use anyhow::Result;
use std::collections::HashMap;

/// First-start → last-finish span of one contiguous task-id range — a
/// lowering phase of a hierarchical collective, or one op of a fused
/// stream batch. Under the barriered hierarchical lowering phases abut
/// (one span's `end` is the next phase's gate); under chunk pipelining —
/// and under concurrent stream execution — spans interleave, so a single
/// timestamp cannot describe them. Shared by [`HierReport`] and the
/// per-op spans of the stream scheduler (one definition, one query path:
/// [`phase_span`] over [`Schedule::range_span`]).
///
/// [`HierReport`]: super::hierarchical::HierReport
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PhaseSpan {
    pub start: SimTime,
    pub end: SimTime,
}

impl PhaseSpan {
    /// The absent phase (degenerate single-node runs, or an operator
    /// without that phase).
    pub const EMPTY: PhaseSpan = PhaseSpan {
        start: SimTime::ZERO,
        end: SimTime::ZERO,
    };

    /// Busy length of the span (saturating; EMPTY → ZERO).
    pub fn duration(self) -> SimTime {
        self.end.saturating_sub(self.start)
    }

    pub fn is_empty(self) -> bool {
        self == Self::EMPTY
    }

    /// The span shifted `earlier` leftward (saturating at zero) — how a
    /// batch-relative span becomes op-relative.
    pub fn rebased(self, earlier: SimTime) -> PhaseSpan {
        PhaseSpan {
            start: self.start.saturating_sub(earlier),
            end: self.end.saturating_sub(earlier),
        }
    }
}

/// Span of the tasks whose ids fall in `range` on an executed schedule;
/// [`PhaseSpan::EMPTY`] for an empty or out-of-bounds range.
pub fn phase_span(sched: &Schedule, range: std::ops::Range<usize>) -> PhaseSpan {
    sched
        .range_span(range)
        .map(|(start, end)| PhaseSpan { start, end })
        .unwrap_or(PhaseSpan::EMPTY)
}

/// Byte-interval → producing-chunk index: the reusable joint between two
/// pipelined schedule stages whose chunk grids disagree.
///
/// A producing stage registers, per emitted chunk, the byte interval it
/// covers (in whatever linear coordinate space the caller picks) and the
/// task whose completion makes those bytes available. A consuming stage
/// then asks, per *its own* chunks, which producer tasks overlap — the
/// per-chunk dependency lists that let a cross-node stripe start the
/// moment the intra-phase chunks feeding it finish, instead of waiting
/// behind a whole-phase barrier. Mismatched chunk sizes across tiers
/// (1 MiB intra staging vs. NIC-stripe sub-blocks, say) are the normal
/// case: overlap is resolved at byte granularity.
///
/// Intervals may overlap (several producers of the same bytes — e.g. the
/// same slice arriving from every node of an allgather ring); a query
/// returns every overlapping producer, sorted and deduplicated.
#[derive(Debug, Clone, Default)]
pub struct ChunkMap {
    /// (offset, len, producer); `len > 0` by construction.
    entries: Vec<(u64, u64, TaskId)>,
}

impl ChunkMap {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Register one producer covering `[offset, offset + len)`.
    /// Zero-length registrations are dropped (a zero-byte chunk produces
    /// nothing a consumer could wait for).
    pub fn insert(&mut self, offset: u64, len: u64, task: TaskId) {
        if len > 0 {
            self.entries.push((offset, len, task));
        }
    }

    /// Register a chunk-aligned task list starting at `offset`:
    /// `tasks[c]` produces the `sizes[c]`-byte chunk at the running
    /// offset. `sizes` and `tasks` must be parallel (the shape both
    /// `ring::chunk_sizes` and the graph builders emit).
    pub fn insert_chunks(&mut self, offset: u64, sizes: &[u64], tasks: &[TaskId]) {
        debug_assert_eq!(sizes.len(), tasks.len(), "chunk sizes/tasks mismatch");
        let mut off = offset;
        for (sz, t) in sizes.iter().zip(tasks) {
            self.insert(off, *sz, *t);
            off += sz;
        }
    }

    /// Every producer overlapping `[lo, hi)`, sorted and deduplicated.
    /// Empty when the interval is empty or nothing covers it.
    pub fn producers(&self, lo: u64, hi: u64) -> Vec<TaskId> {
        if hi <= lo {
            return Vec::new();
        }
        let mut out: Vec<TaskId> = self
            .entries
            .iter()
            .filter(|(off, len, _)| *off < hi && off + len > lo)
            .map(|(_, _, t)| *t)
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Per-chunk dependency lists for a consumer whose chunk grid starts
    /// at `offset` with the given `sizes` — the shape the graph builders'
    /// `deps_per_chunk` parameters expect.
    pub fn deps_for_chunks(&self, offset: u64, sizes: &[u64]) -> Vec<Vec<TaskId>> {
        let mut out = Vec::with_capacity(sizes.len());
        let mut off = offset;
        for sz in sizes {
            out.push(self.producers(off, off + sz));
            off += sz;
        }
        out
    }
}

/// Traffic assigned to one path by the balancer.
#[derive(Debug, Clone, Copy)]
pub struct PathAssignment {
    pub path: PathId,
    pub bytes: u64,
    pub model: PathModel,
}

/// One multi-path collective invocation.
#[derive(Debug, Clone)]
pub struct MultipathSpec {
    pub kind: CollectiveKind,
    pub n: usize,
    /// Total message bytes (paper convention per operator).
    pub msg_bytes: u64,
    /// Lowering algorithm for every path of this call (selected per
    /// size bucket by [`super::algo::AlgoTable`], or pinned via
    /// `algo = "…"` / `--algo`). [`Algo::Ring`] reproduces the
    /// pre-algorithm schedules bit-identically.
    pub algo: Algo,
    /// Active paths; `bytes` must sum to `msg_bytes`.
    pub paths: Vec<PathAssignment>,
    /// Fair-share weight stamped on every *physical-link* flow this call
    /// emits (`1.0` = legacy behavior, bit-identical). The serve QoS
    /// layer sets this per tenant so shared lanes split by tenant weight
    /// under max–min fair share, while the per-op protocol resources —
    /// private to the call — are unaffected by construction (a private
    /// resource has one flow, and any positive weight gets it the full
    /// capacity).
    pub weight: f64,
}

impl MultipathSpec {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.n >= 2, "collective needs ≥2 ranks");
        anyhow::ensure!(!self.paths.is_empty(), "no active paths");
        let sum: u64 = self.paths.iter().map(|p| p.bytes).sum();
        anyhow::ensure!(
            sum == self.msg_bytes,
            "path bytes {} != message bytes {}",
            sum,
            self.msg_bytes
        );
        anyhow::ensure!(
            self.weight.is_finite() && self.weight > 0.0,
            "flow weight must be finite and > 0 (got {})",
            self.weight
        );
        Ok(())
    }

    /// Builder: same spec with a different fair-share weight.
    pub fn with_weight(mut self, weight: f64) -> Self {
        self.weight = weight;
        self
    }
}

/// Completion of one path within a collective.
#[derive(Debug, Clone, Copy)]
pub struct PathTiming {
    pub path: PathId,
    pub bytes: u64,
    pub time: SimTime,
}

/// DES outcome of one collective.
#[derive(Debug, Clone)]
pub struct SimOutcome {
    /// Slowest path = collective completion.
    pub total: SimTime,
    pub per_path: Vec<PathTiming>,
    pub events: u64,
    pub tasks: usize,
}

impl SimOutcome {
    pub fn time_of(&self, path: PathId) -> Option<SimTime> {
        self.per_path.iter().find(|p| p.path == path).map(|p| p.time)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum Dir {
    Up,
    Down,
}

/// Builds the combined task graph for one collective invocation.
pub struct GraphBuilder<'t> {
    pub topo: &'t Topology,
    pub pool: ResourcePool,
    pub graph: TaskGraph,
    pub n: usize,
    models: HashMap<PathId, PathModel>,
    proto: HashMap<(PathId, usize, Dir), ResourceId>,
    reduce_bps: f64,
    /// Fair-share weight for every Transfer this builder emits
    /// (per-tenant QoS; 1.0 = legacy). Set by [`append_call`] from the
    /// spec, or directly via [`Self::set_weight`] by callers that lower
    /// without a [`MultipathSpec`] (the hierarchical node builder).
    weight: f64,
}

impl<'t> GraphBuilder<'t> {
    pub fn new(
        topo: &'t Topology,
        n: usize,
        models: &[(PathId, PathModel)],
        reduce_bps: f64,
    ) -> Self {
        Self::onto(topo, n, models, reduce_bps, topo.pool.clone(), TaskGraph::new())
    }

    /// Build onto an existing (pool, graph) — the fused `group_end`
    /// launch compiles several collectives into ONE graph this way: each
    /// call gets its own protocol-stream resources (its own CUDA
    /// streams, in hardware terms) while the raw physical links stay
    /// shared, so concurrent collectives contend for the same lanes
    /// under max–min fair share.
    pub fn onto(
        topo: &'t Topology,
        n: usize,
        models: &[(PathId, PathModel)],
        reduce_bps: f64,
        mut pool: ResourcePool,
        graph: TaskGraph,
    ) -> Self {
        assert!(n >= 2 && n <= topo.n_gpus());
        let mut proto = HashMap::new();
        for (path, model) in models {
            for g in 0..n {
                proto.insert(
                    (*path, g, Dir::Up),
                    pool.add(format!("proto.{path}.up.gpu{g}"), model.rate_cap),
                );
                if *path == PathId::Pcie {
                    // Staged path caps its ingress leg independently.
                    proto.insert(
                        (*path, g, Dir::Down),
                        pool.add(format!("proto.{path}.down.gpu{g}"), model.rate_cap),
                    );
                }
            }
        }
        GraphBuilder {
            topo,
            pool,
            graph,
            n,
            models: models.iter().copied().collect(),
            proto,
            reduce_bps,
            weight: 1.0,
        }
    }

    /// Hand the accumulated (pool, graph) back for further fused calls.
    pub fn into_parts(self) -> (ResourcePool, TaskGraph) {
        (self.pool, self.graph)
    }

    /// Set the fair-share weight stamped on subsequently emitted
    /// transfers (must be finite and > 0; debug-asserted here, enforced
    /// upstream by `MultipathSpec::validate` / `FlowSim::add_capped`).
    pub fn set_weight(&mut self, weight: f64) {
        debug_assert!(weight.is_finite() && weight > 0.0);
        self.weight = weight;
    }

    pub fn model(&self, path: PathId) -> PathModel {
        self.models[&path]
    }

    fn proto_res(&self, path: PathId, gpu: usize, dir: Dir) -> ResourceId {
        self.proto[&(path, gpu, dir)]
    }

    /// Chunk lengths for one ring-step block on `path`.
    pub fn chunks_for(&self, path: PathId, block: u64) -> Vec<u64> {
        chunk_sizes(block, self.models[&path].chunk_bytes)
    }

    /// Emit the tasks that move one ring-step block `src → dst` on `path`.
    ///
    /// `deps_per_chunk`: per-chunk "data available at src" dependencies
    /// (from the previous ring step); empty slice when the data is locally
    /// resident. `charge_step_latency` attaches the path's per-step α to
    /// the first chunk. `reduce_after` appends the staged-path reduction
    /// cost (ReduceScatter consumer combining out of the staging buffer).
    ///
    /// Returns the per-chunk "data available at dst" task ids.
    pub fn send_block(
        &mut self,
        path: PathId,
        src: usize,
        dst: usize,
        block: u64,
        deps_per_chunk: &[Vec<TaskId>],
        charge_step_latency: bool,
        reduce_after: bool,
        tag: u32,
    ) -> Vec<TaskId> {
        self.send_block_capped(
            path,
            src,
            dst,
            block,
            deps_per_chunk,
            charge_step_latency,
            reduce_after,
            tag,
            f64::INFINITY,
        )
    }

    /// As [`Self::send_block`], with an additional per-flow rate cap on
    /// every emitted transfer — how non-contiguous lowerings (the
    /// halving-doubling family, [`super::algo::HD_EFF`]) charge their
    /// strided-segment streaming penalty without touching the path's
    /// shared protocol resources.
    #[allow(clippy::too_many_arguments)]
    pub fn send_block_capped(
        &mut self,
        path: PathId,
        src: usize,
        dst: usize,
        block: u64,
        deps_per_chunk: &[Vec<TaskId>],
        charge_step_latency: bool,
        reduce_after: bool,
        tag: u32,
        rate_cap: f64,
    ) -> Vec<TaskId> {
        let model = self.models[&path];
        let weight = self.weight;
        let sizes = self.chunks_for(path, block);
        debug_assert!(deps_per_chunk.is_empty() || deps_per_chunk.len() == sizes.len());
        let mut arrivals = Vec::with_capacity(sizes.len());
        // Slot-reuse gating for the double-buffered staged path.
        let mut h2d_ids: Vec<TaskId> = Vec::new();

        // Per-step protocol latency gates *every* chunk of the step (the
        // launch/doorbell happens before any byte moves); it fires once
        // the step's first data is available at the sender. RS-phase
        // steps additionally pay the staged read-modify-write combine
        // coordination cost (see links::calib).
        let step_lat = if reduce_after {
            model.step_latency + model.reduce_step_latency
        } else {
            model.step_latency
        };
        let gate: Option<TaskId> = if charge_step_latency && step_lat > SimTime::ZERO {
            let gate_deps = deps_per_chunk.first().cloned().unwrap_or_default();
            Some(self.graph.add_tagged(
                TaskKind::Delay { duration: step_lat },
                gate_deps,
                tag,
            ))
        } else {
            None
        };

        // FIFO egress: chunk c may not start before chunk c-1 left the
        // sender (real rings stream chunks in order; without this, fair
        // sharing would let all chunks finish simultaneously and the
        // cross-step pipeline could never fill).
        let mut prev_egress: Option<TaskId> = None;

        for (c, &bytes) in sizes.iter().enumerate() {
            let latency = SimTime::ZERO;
            let mut deps: Vec<TaskId> = deps_per_chunk.get(c).cloned().unwrap_or_default();
            if let Some(g) = gate {
                deps.push(g);
            }
            if let Some(pe) = prev_egress {
                deps.push(pe);
            }

            let arrival = match path {
                PathId::Nvlink => {
                    let route = vec![
                        self.proto_res(path, src, Dir::Up),
                        self.topo.nvlink_up[src],
                        self.topo.nvlink_down[dst],
                    ];
                    let t = self.graph.add_tagged(
                        TaskKind::Transfer {
                            bytes,
                            route,
                            weight,
                            latency,
                            rate_cap,
                        },
                        deps,
                        tag,
                    );
                    prev_egress = Some(t);
                    t
                }
                PathId::Pcie => {
                    // Producer-D2H into the pinned buffer on src's NUMA
                    // node, then H2CD out of it — double-buffered: chunk c
                    // may not stage until chunk c-2 has drained (§3.1).
                    if c >= 2 {
                        deps.push(h2d_ids[c - 2]);
                    }
                    let mut d2h_route = vec![self.proto_res(path, src, Dir::Up)];
                    d2h_route.extend(self.topo.pcie_d2h_route(src));
                    let d2h = self.graph.add_tagged(
                        TaskKind::Transfer {
                            bytes,
                            route: d2h_route,
                            weight,
                            latency,
                            rate_cap,
                        },
                        deps,
                        tag,
                    );
                    prev_egress = Some(d2h);
                    let mut h2d_route = vec![self.proto_res(path, dst, Dir::Down)];
                    h2d_route.extend(self.topo.pcie_h2d_route(src, dst));
                    let h2d = self.graph.add_tagged(
                        TaskKind::Transfer {
                            bytes,
                            route: h2d_route,
                            weight,
                            latency: SimTime::ZERO,
                            rate_cap,
                        },
                        vec![d2h],
                        tag,
                    );
                    h2d_ids.push(h2d);
                    if reduce_after && bytes > 0 {
                        // Consumer combines the staged chunk into its
                        // accumulator at host-read speed.
                        self.graph.add_tagged(
                            TaskKind::Delay {
                                duration: SimTime::for_transfer(bytes, self.reduce_bps),
                            },
                            vec![h2d],
                            tag,
                        )
                    } else {
                        h2d
                    }
                }
                PathId::Rdma => {
                    let mut route = vec![self.proto_res(path, src, Dir::Up)];
                    route.extend(self.topo.rdma_route(src, dst));
                    let t = self.graph.add_tagged(
                        TaskKind::Transfer {
                            bytes,
                            route,
                            weight,
                            latency,
                            rate_cap,
                        },
                        deps,
                        tag,
                    );
                    prev_egress = Some(t);
                    t
                }
            };
            arrivals.push(arrival);
        }
        arrivals
    }
}

/// Emit one collective's tasks into `b`, tagging each (call, path) as
/// `tag_base + path.tag()` so fused launches can attribute finishes.
/// This is the compiled form of one single-node collective — the stream
/// scheduler appends one per enqueued op into a shared (pool, graph)
/// with `tag_base = 0` and disambiguates by task-id range instead of by
/// tag ([`crate::sim::Schedule::tag_finish_in`]). The per-kind lowering
/// is dispatched through the [`super::algo`] registry under the spec's
/// algorithm.
pub fn append_call(b: &mut GraphBuilder<'_>, spec: &MultipathSpec, tag_base: u32) {
    b.set_weight(spec.weight);
    for pa in &spec.paths {
        if pa.bytes == 0 {
            continue;
        }
        let tag = tag_base + pa.path.tag();
        algo::lower(b, spec.kind, spec.algo, pa.path, pa.bytes, tag);
    }
}

/// Total bytes routed over each *physical* resource of `graph`, keyed by
/// resource name — per-op protocol resources (`proto.*`) are filtered
/// out, leaving the fabric links the serve harness reports utilization
/// for. A transfer crossing k physical links contributes its bytes to
/// each (link-level accounting, like NIC counters).
pub fn link_bytes(pool: &ResourcePool, graph: &TaskGraph) -> Vec<(String, u64)> {
    graph
        .resource_bytes()
        .into_iter()
        .filter_map(|(id, bytes)| {
            let name = &pool.get(id).name;
            if bytes > 0 && !name.starts_with("proto.") {
                Some((name.clone(), bytes))
            } else {
                None
            }
        })
        .collect()
}

/// Execute one multi-path collective on the DES; returns per-path times.
pub fn simulate(topo: &Topology, spec: &MultipathSpec, reduce_bps: f64) -> Result<SimOutcome> {
    simulate_traced(topo, spec, reduce_bps).map(|(out, _)| out)
}

/// As [`simulate`], additionally reporting per-physical-link byte
/// totals ([`link_bytes`]) — the fabric-accounting variant the stream
/// scheduler uses when a `SimDevice` has byte accounting enabled.
pub fn simulate_traced(
    topo: &Topology,
    spec: &MultipathSpec,
    reduce_bps: f64,
) -> Result<(SimOutcome, Vec<(String, u64)>)> {
    spec.validate()?;
    let models: Vec<(PathId, PathModel)> =
        spec.paths.iter().map(|p| (p.path, p.model)).collect();
    let mut b = GraphBuilder::new(topo, spec.n, &models, reduce_bps);
    append_call(&mut b, spec, 0);
    let tasks = b.graph.len();
    let bytes = link_bytes(&b.pool, &b.graph);
    let sched = Engine::new(&b.pool).run(&b.graph)?;
    let per_path = spec
        .paths
        .iter()
        .map(|pa| PathTiming {
            path: pa.path,
            bytes: pa.bytes,
            time: sched
                .tag_finish(&b.graph, pa.path.tag())
                .unwrap_or(SimTime::ZERO),
        })
        .collect::<Vec<_>>();
    Ok((
        SimOutcome {
            total: sched.makespan,
            per_path,
            events: sched.events,
            tasks,
        },
        bytes,
    ))
}

// NOTE: the old `simulate_group` fused-launch compiler (tag-stride
// attribution) was deleted when `group_start`/`group_end` were rebuilt
// over the stream scheduler — fused launches now compile through
// `comm::stream::SimDevice`, which appends per-op fragments with
// [`append_call`] / `ClusterCollective::compile_onto` and attributes
// per-op completion by task-id range (`Schedule::tag_finish_in`), so
// there is exactly ONE implementation of concurrent-collective pricing.

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::Preset;
    use crate::links::calib::Calibration;

    fn h800() -> Topology {
        Topology::build(&Preset::H800.spec())
    }

    fn nv_model(kind: CollectiveKind, n: usize, topo: &Topology) -> PathModel {
        Calibration::h800().nvlink_model(kind, n, topo.spec.nvlink_unidir_bps())
    }

    #[test]
    fn allgather_nvlink_only_matches_alpha_beta_model() {
        // 8-GPU AG, 256 MB per rank, NVLink only: the DES should land on
        // t ≈ 7α + 7S/B_eff — the α-β fit the calibration encodes.
        let topo = h800();
        let kind = CollectiveKind::AllGather;
        let model = nv_model(kind, 8, &topo);
        let s = 256u64 << 20;
        let spec = MultipathSpec {
            kind,
            n: 8,
            msg_bytes: s,
            algo: Algo::Ring,
            paths: vec![PathAssignment {
                path: PathId::Nvlink,
                bytes: s,
                model,
            }],
            weight: 1.0,
        };
        let out = simulate(&topo, &spec, 60e9).unwrap();
        let expect = 7.0 * 12e-6 + 7.0 * s as f64 / 148e9;
        let got = out.total.as_secs_f64();
        assert!(
            (got - expect).abs() / expect < 0.05,
            "got {got:.6}, expect {expect:.6}"
        );
        // Paper reports 21 GB/s algbw for this configuration.
        let algbw = kind.algbw_gbps(s, got);
        assert!((algbw - 21.0).abs() < 1.5, "algbw {algbw:.1} vs paper 21");
    }

    #[test]
    fn allreduce_nvlink_only_matches_paper_baseline() {
        // AR 2 GPUs 256 MB → paper NCCL column says 139 GB/s.
        let topo = h800();
        let kind = CollectiveKind::AllReduce;
        let model = nv_model(kind, 2, &topo);
        let s = 256u64 << 20;
        let spec = MultipathSpec {
            kind,
            n: 2,
            msg_bytes: s,
            algo: Algo::Ring,
            paths: vec![PathAssignment {
                path: PathId::Nvlink,
                bytes: s,
                model,
            }],
            weight: 1.0,
        };
        let out = simulate(&topo, &spec, 60e9).unwrap();
        let algbw = kind.algbw_gbps(s, out.total.as_secs_f64());
        assert!((algbw - 139.0).abs() < 8.0, "algbw {algbw:.1} vs paper 139");
    }

    #[test]
    fn multipath_paths_report_separate_times() {
        let topo = h800();
        let kind = CollectiveKind::AllGather;
        let calib = Calibration::h800();
        let s = 64u64 << 20;
        let nv = nv_model(kind, 4, &topo);
        let pcie = calib.pcie_model(topo.spec.pcie_unidir_bps(), 4);
        let spec = MultipathSpec {
            kind,
            n: 4,
            msg_bytes: s,
            algo: Algo::Ring,
            paths: vec![
                PathAssignment {
                    path: PathId::Nvlink,
                    bytes: s * 9 / 10,
                    model: nv,
                },
                PathAssignment {
                    path: PathId::Pcie,
                    bytes: s - s * 9 / 10,
                    model: pcie,
                },
            ],
            weight: 1.0,
        };
        let out = simulate(&topo, &spec, 60e9).unwrap();
        let t_nv = out.time_of(PathId::Nvlink).unwrap();
        let t_pcie = out.time_of(PathId::Pcie).unwrap();
        assert!(t_nv > SimTime::ZERO && t_pcie > SimTime::ZERO);
        assert_eq!(out.total, t_nv.max(t_pcie));
    }

    // (The old simulate_group fused-launch tests moved up the stack:
    // comm::tests::group_fuses_calls_and_never_loses_to_sequential and
    // tests/prop_streams.rs cover fused-vs-sequential and the solo
    // degenerate case against the stream scheduler, which is now the
    // only fused-launch implementation.)

    #[test]
    fn chunk_map_joins_mismatched_grids() {
        // Producer grid: 4 × 4-byte chunks over [0, 16). Consumer grid:
        // 3-byte chunks — every consumer chunk picks up exactly the
        // producers its bytes straddle.
        let mut m = ChunkMap::new();
        let tasks: Vec<TaskId> = (0..4u32).map(TaskId).collect();
        m.insert_chunks(0, &[4, 4, 4, 4], &tasks);
        assert_eq!(m.len(), 4);
        let deps = m.deps_for_chunks(0, &[3, 3, 3, 3, 3, 1]);
        assert_eq!(deps[0], vec![TaskId(0)]); // [0,3)
        assert_eq!(deps[1], vec![TaskId(0), TaskId(1)]); // [3,6)
        assert_eq!(deps[2], vec![TaskId(1), TaskId(2)]); // [6,9)
        assert_eq!(deps[3], vec![TaskId(2)]); // [9,12)
        assert_eq!(deps[4], vec![TaskId(3)]); // [12,15)
        assert_eq!(deps[5], vec![TaskId(3)]); // [15,16)
        // Out-of-coverage and empty queries come back empty.
        assert!(m.producers(16, 20).is_empty());
        assert!(m.producers(5, 5).is_empty());
    }

    #[test]
    fn chunk_map_overlapping_producers_dedup() {
        // Two copies of the same interval (allgather: every node's copy
        // of a slice) plus a zero-length chunk that must vanish.
        let mut m = ChunkMap::new();
        m.insert(0, 8, TaskId(7));
        m.insert(0, 8, TaskId(3));
        m.insert(4, 0, TaskId(9));
        let p = m.producers(2, 6);
        assert_eq!(p, vec![TaskId(3), TaskId(7)]);
        assert_eq!(m.len(), 2, "zero-length entry must be dropped");
    }

    #[test]
    fn mismatched_bytes_rejected() {
        let topo = h800();
        let spec = MultipathSpec {
            kind: CollectiveKind::AllGather,
            n: 4,
            msg_bytes: 100,
            algo: Algo::Ring,
            paths: vec![PathAssignment {
                path: PathId::Nvlink,
                bytes: 60,
                model: nv_model(CollectiveKind::AllGather, 4, &topo),
            }],
            weight: 1.0,
        };
        assert!(simulate(&topo, &spec, 60e9).is_err());
    }
}
