//! Timing-graph compilation for multi-path collectives.
//!
//! [`GraphBuilder`] clones the node's raw resource pool and adds, per
//! (path, GPU, direction), a *protocol resource* whose capacity is the
//! path's calibrated effective rate. Chunk flows route through both their
//! protocol resource and the raw physical links, so
//!
//! * a path never exceeds its single-stream protocol efficiency (§2.2.3 —
//!   and extra parallel streams on one path gain nothing, reproducing the
//!   CUDA-driver serialization observation), and
//! * different paths still contend for the *shared physical lane*
//!   (GPU→NIC and GPU→host both crossing `pcie.up[g]`, §2.2.2).
//!
//! [`simulate`] executes one multi-path collective and reports per-path
//! completion times — the observable the two-stage balancer consumes.

use super::ring::chunk_sizes;
use super::CollectiveKind;
use crate::links::{PathId, PathModel};
use crate::sim::{Engine, ResourceId, ResourcePool, SimTime, TaskGraph, TaskId, TaskKind};
use crate::topology::Topology;
use anyhow::Result;
use std::collections::HashMap;

/// Traffic assigned to one path by the balancer.
#[derive(Debug, Clone, Copy)]
pub struct PathAssignment {
    pub path: PathId,
    pub bytes: u64,
    pub model: PathModel,
}

/// One multi-path collective invocation.
#[derive(Debug, Clone)]
pub struct MultipathSpec {
    pub kind: CollectiveKind,
    pub n: usize,
    /// Total message bytes (paper convention per operator).
    pub msg_bytes: u64,
    /// Active paths; `bytes` must sum to `msg_bytes`.
    pub paths: Vec<PathAssignment>,
}

impl MultipathSpec {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.n >= 2, "collective needs ≥2 ranks");
        anyhow::ensure!(!self.paths.is_empty(), "no active paths");
        let sum: u64 = self.paths.iter().map(|p| p.bytes).sum();
        anyhow::ensure!(
            sum == self.msg_bytes,
            "path bytes {} != message bytes {}",
            sum,
            self.msg_bytes
        );
        Ok(())
    }
}

/// Completion of one path within a collective.
#[derive(Debug, Clone, Copy)]
pub struct PathTiming {
    pub path: PathId,
    pub bytes: u64,
    pub time: SimTime,
}

/// DES outcome of one collective.
#[derive(Debug, Clone)]
pub struct SimOutcome {
    /// Slowest path = collective completion.
    pub total: SimTime,
    pub per_path: Vec<PathTiming>,
    pub events: u64,
    pub tasks: usize,
}

impl SimOutcome {
    pub fn time_of(&self, path: PathId) -> Option<SimTime> {
        self.per_path.iter().find(|p| p.path == path).map(|p| p.time)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum Dir {
    Up,
    Down,
}

/// Builds the combined task graph for one collective invocation.
pub struct GraphBuilder<'t> {
    pub topo: &'t Topology,
    pub pool: ResourcePool,
    pub graph: TaskGraph,
    pub n: usize,
    models: HashMap<PathId, PathModel>,
    proto: HashMap<(PathId, usize, Dir), ResourceId>,
    reduce_bps: f64,
}

impl<'t> GraphBuilder<'t> {
    pub fn new(
        topo: &'t Topology,
        n: usize,
        models: &[(PathId, PathModel)],
        reduce_bps: f64,
    ) -> Self {
        assert!(n >= 2 && n <= topo.n_gpus());
        let mut pool = topo.pool.clone();
        let mut proto = HashMap::new();
        for (path, model) in models {
            for g in 0..n {
                proto.insert(
                    (*path, g, Dir::Up),
                    pool.add(format!("proto.{path}.up.gpu{g}"), model.rate_cap),
                );
                if *path == PathId::Pcie {
                    // Staged path caps its ingress leg independently.
                    proto.insert(
                        (*path, g, Dir::Down),
                        pool.add(format!("proto.{path}.down.gpu{g}"), model.rate_cap),
                    );
                }
            }
        }
        GraphBuilder {
            topo,
            pool,
            graph: TaskGraph::new(),
            n,
            models: models.iter().copied().collect(),
            proto,
            reduce_bps,
        }
    }

    pub fn model(&self, path: PathId) -> PathModel {
        self.models[&path]
    }

    fn proto_res(&self, path: PathId, gpu: usize, dir: Dir) -> ResourceId {
        self.proto[&(path, gpu, dir)]
    }

    /// Chunk lengths for one ring-step block on `path`.
    pub fn chunks_for(&self, path: PathId, block: u64) -> Vec<u64> {
        chunk_sizes(block, self.models[&path].chunk_bytes)
    }

    /// Emit the tasks that move one ring-step block `src → dst` on `path`.
    ///
    /// `deps_per_chunk`: per-chunk "data available at src" dependencies
    /// (from the previous ring step); empty slice when the data is locally
    /// resident. `charge_step_latency` attaches the path's per-step α to
    /// the first chunk. `reduce_after` appends the staged-path reduction
    /// cost (ReduceScatter consumer combining out of the staging buffer).
    ///
    /// Returns the per-chunk "data available at dst" task ids.
    pub fn send_block(
        &mut self,
        path: PathId,
        src: usize,
        dst: usize,
        block: u64,
        deps_per_chunk: &[Vec<TaskId>],
        charge_step_latency: bool,
        reduce_after: bool,
        tag: u32,
    ) -> Vec<TaskId> {
        let model = self.models[&path];
        let sizes = self.chunks_for(path, block);
        debug_assert!(deps_per_chunk.is_empty() || deps_per_chunk.len() == sizes.len());
        let mut arrivals = Vec::with_capacity(sizes.len());
        // Slot-reuse gating for the double-buffered staged path.
        let mut h2d_ids: Vec<TaskId> = Vec::new();

        // Per-step protocol latency gates *every* chunk of the step (the
        // launch/doorbell happens before any byte moves); it fires once
        // the step's first data is available at the sender. RS-phase
        // steps additionally pay the staged read-modify-write combine
        // coordination cost (see links::calib).
        let step_lat = if reduce_after {
            model.step_latency + model.reduce_step_latency
        } else {
            model.step_latency
        };
        let gate: Option<TaskId> = if charge_step_latency && step_lat > SimTime::ZERO {
            let gate_deps = deps_per_chunk.first().cloned().unwrap_or_default();
            Some(self.graph.add_tagged(
                TaskKind::Delay { duration: step_lat },
                gate_deps,
                tag,
            ))
        } else {
            None
        };

        // FIFO egress: chunk c may not start before chunk c-1 left the
        // sender (real rings stream chunks in order; without this, fair
        // sharing would let all chunks finish simultaneously and the
        // cross-step pipeline could never fill).
        let mut prev_egress: Option<TaskId> = None;

        for (c, &bytes) in sizes.iter().enumerate() {
            let latency = SimTime::ZERO;
            let mut deps: Vec<TaskId> = deps_per_chunk.get(c).cloned().unwrap_or_default();
            if let Some(g) = gate {
                deps.push(g);
            }
            if let Some(pe) = prev_egress {
                deps.push(pe);
            }

            let arrival = match path {
                PathId::Nvlink => {
                    let route = vec![
                        self.proto_res(path, src, Dir::Up),
                        self.topo.nvlink_up[src],
                        self.topo.nvlink_down[dst],
                    ];
                    let t = self.graph.add_tagged(
                        TaskKind::Transfer {
                            bytes,
                            route,
                            weight: 1.0,
                            latency,
                            rate_cap: f64::INFINITY,
                        },
                        deps,
                        tag,
                    );
                    prev_egress = Some(t);
                    t
                }
                PathId::Pcie => {
                    // Producer-D2H into the pinned buffer on src's NUMA
                    // node, then H2CD out of it — double-buffered: chunk c
                    // may not stage until chunk c-2 has drained (§3.1).
                    if c >= 2 {
                        deps.push(h2d_ids[c - 2]);
                    }
                    let mut d2h_route = vec![self.proto_res(path, src, Dir::Up)];
                    d2h_route.extend(self.topo.pcie_d2h_route(src));
                    let d2h = self.graph.add_tagged(
                        TaskKind::Transfer {
                            bytes,
                            route: d2h_route,
                            weight: 1.0,
                            latency,
                            rate_cap: f64::INFINITY,
                        },
                        deps,
                        tag,
                    );
                    prev_egress = Some(d2h);
                    let mut h2d_route = vec![self.proto_res(path, dst, Dir::Down)];
                    h2d_route.extend(self.topo.pcie_h2d_route(src, dst));
                    let h2d = self.graph.add_tagged(
                        TaskKind::Transfer {
                            bytes,
                            route: h2d_route,
                            weight: 1.0,
                            latency: SimTime::ZERO,
                            rate_cap: f64::INFINITY,
                        },
                        vec![d2h],
                        tag,
                    );
                    h2d_ids.push(h2d);
                    if reduce_after && bytes > 0 {
                        // Consumer combines the staged chunk into its
                        // accumulator at host-read speed.
                        self.graph.add_tagged(
                            TaskKind::Delay {
                                duration: SimTime::for_transfer(bytes, self.reduce_bps),
                            },
                            vec![h2d],
                            tag,
                        )
                    } else {
                        h2d
                    }
                }
                PathId::Rdma => {
                    let mut route = vec![self.proto_res(path, src, Dir::Up)];
                    route.extend(self.topo.rdma_route(src, dst));
                    let t = self.graph.add_tagged(
                        TaskKind::Transfer {
                            bytes,
                            route,
                            weight: 1.0,
                            latency,
                            rate_cap: f64::INFINITY,
                        },
                        deps,
                        tag,
                    );
                    prev_egress = Some(t);
                    t
                }
            };
            arrivals.push(arrival);
        }
        arrivals
    }
}

/// Execute one multi-path collective on the DES; returns per-path times.
pub fn simulate(topo: &Topology, spec: &MultipathSpec, reduce_bps: f64) -> Result<SimOutcome> {
    spec.validate()?;
    let models: Vec<(PathId, PathModel)> =
        spec.paths.iter().map(|p| (p.path, p.model)).collect();
    let mut b = GraphBuilder::new(topo, spec.n, &models, reduce_bps);
    for pa in &spec.paths {
        if pa.bytes == 0 {
            continue;
        }
        let tag = pa.path.tag();
        match spec.kind {
            CollectiveKind::AllGather => {
                super::allgather::build_tasks(&mut b, pa.path, pa.bytes, tag)
            }
            CollectiveKind::AllReduce => {
                super::allreduce::build_tasks(&mut b, pa.path, pa.bytes, tag)
            }
            CollectiveKind::ReduceScatter => {
                super::reduce_scatter::build_tasks(&mut b, pa.path, pa.bytes, tag)
            }
            CollectiveKind::Broadcast => {
                super::broadcast::build_tasks(&mut b, pa.path, pa.bytes, tag)
            }
            CollectiveKind::AllToAll => {
                super::alltoall::build_tasks(&mut b, pa.path, pa.bytes, tag)
            }
        }
    }
    let tasks = b.graph.len();
    let sched = Engine::new(&b.pool).run(&b.graph)?;
    let per_path = spec
        .paths
        .iter()
        .map(|pa| PathTiming {
            path: pa.path,
            bytes: pa.bytes,
            time: sched
                .tag_finish(&b.graph, pa.path.tag())
                .unwrap_or(SimTime::ZERO),
        })
        .collect::<Vec<_>>();
    Ok(SimOutcome {
        total: sched.makespan,
        per_path,
        events: sched.events,
        tasks,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::Preset;
    use crate::links::calib::Calibration;

    fn h800() -> Topology {
        Topology::build(&Preset::H800.spec())
    }

    fn nv_model(kind: CollectiveKind, n: usize, topo: &Topology) -> PathModel {
        Calibration::h800().nvlink_model(kind, n, topo.spec.nvlink_unidir_bps())
    }

    #[test]
    fn allgather_nvlink_only_matches_alpha_beta_model() {
        // 8-GPU AG, 256 MB per rank, NVLink only: the DES should land on
        // t ≈ 7α + 7S/B_eff — the α-β fit the calibration encodes.
        let topo = h800();
        let kind = CollectiveKind::AllGather;
        let model = nv_model(kind, 8, &topo);
        let s = 256u64 << 20;
        let spec = MultipathSpec {
            kind,
            n: 8,
            msg_bytes: s,
            paths: vec![PathAssignment {
                path: PathId::Nvlink,
                bytes: s,
                model,
            }],
        };
        let out = simulate(&topo, &spec, 60e9).unwrap();
        let expect = 7.0 * 12e-6 + 7.0 * s as f64 / 148e9;
        let got = out.total.as_secs_f64();
        assert!(
            (got - expect).abs() / expect < 0.05,
            "got {got:.6}, expect {expect:.6}"
        );
        // Paper reports 21 GB/s algbw for this configuration.
        let algbw = kind.algbw_gbps(s, got);
        assert!((algbw - 21.0).abs() < 1.5, "algbw {algbw:.1} vs paper 21");
    }

    #[test]
    fn allreduce_nvlink_only_matches_paper_baseline() {
        // AR 2 GPUs 256 MB → paper NCCL column says 139 GB/s.
        let topo = h800();
        let kind = CollectiveKind::AllReduce;
        let model = nv_model(kind, 2, &topo);
        let s = 256u64 << 20;
        let spec = MultipathSpec {
            kind,
            n: 2,
            msg_bytes: s,
            paths: vec![PathAssignment {
                path: PathId::Nvlink,
                bytes: s,
                model,
            }],
        };
        let out = simulate(&topo, &spec, 60e9).unwrap();
        let algbw = kind.algbw_gbps(s, out.total.as_secs_f64());
        assert!((algbw - 139.0).abs() < 8.0, "algbw {algbw:.1} vs paper 139");
    }

    #[test]
    fn multipath_paths_report_separate_times() {
        let topo = h800();
        let kind = CollectiveKind::AllGather;
        let calib = Calibration::h800();
        let s = 64u64 << 20;
        let nv = nv_model(kind, 4, &topo);
        let pcie = calib.pcie_model(topo.spec.pcie_unidir_bps(), 4);
        let spec = MultipathSpec {
            kind,
            n: 4,
            msg_bytes: s,
            paths: vec![
                PathAssignment {
                    path: PathId::Nvlink,
                    bytes: s * 9 / 10,
                    model: nv,
                },
                PathAssignment {
                    path: PathId::Pcie,
                    bytes: s - s * 9 / 10,
                    model: pcie,
                },
            ],
        };
        let out = simulate(&topo, &spec, 60e9).unwrap();
        let t_nv = out.time_of(PathId::Nvlink).unwrap();
        let t_pcie = out.time_of(PathId::Pcie).unwrap();
        assert!(t_nv > SimTime::ZERO && t_pcie > SimTime::ZERO);
        assert_eq!(out.total, t_nv.max(t_pcie));
    }

    #[test]
    fn mismatched_bytes_rejected() {
        let topo = h800();
        let spec = MultipathSpec {
            kind: CollectiveKind::AllGather,
            n: 4,
            msg_bytes: 100,
            paths: vec![PathAssignment {
                path: PathId::Nvlink,
                bytes: 60,
                model: nv_model(CollectiveKind::AllGather, 4, &topo),
            }],
        };
        assert!(simulate(&topo, &spec, 60e9).is_err());
    }
}
