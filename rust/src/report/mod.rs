//! Paper-style table/figure rendering for the repro harness.

use std::fmt::Write as _;

/// Fixed-width ASCII table matching the paper's row structure.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "table row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |out: &mut String, cells: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncol {
                let _ = write!(s, " {:<w$} |", cells[i], w = widths[i]);
            }
            let _ = writeln!(out, "{s}");
        };
        line(&mut out, &self.header);
        let _ = writeln!(
            out,
            "|{}|",
            widths
                .iter()
                .map(|w| "-".repeat(w + 2))
                .collect::<Vec<_>>()
                .join("|")
        );
        for r in &self.rows {
            line(&mut out, r);
        }
        out
    }
}

/// Simple horizontal ASCII bar chart (Figure 2-style).
pub fn bar_chart(title: &str, rows: &[(String, f64)], max_width: usize) -> String {
    let peak = rows.iter().map(|r| r.1).fold(0.0f64, f64::max).max(1e-12);
    let label_w = rows.iter().map(|r| r.0.len()).max().unwrap_or(0);
    let mut out = String::new();
    let _ = writeln!(out, "== {title} ==");
    for (label, v) in rows {
        let w = ((v / peak) * max_width as f64).round() as usize;
        let _ = writeln!(out, "{label:<label_w$} | {:<max_width$} {v:.1}", "#".repeat(w));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["op", "bw"]);
        t.row(vec!["allgather".into(), "27".into()]);
        t.row(vec!["ar".into(), "126.5".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("| allgather | 27    |"));
    }

    #[test]
    fn bars_scale_to_peak() {
        let s = bar_chart(
            "B",
            &[("a".into(), 10.0), ("b".into(), 5.0)],
            10,
        );
        assert!(s.contains("##########"));
        assert!(s.contains("#####"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn row_arity_checked() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }
}
