//! Fault injection and recovery — chaos engineering for the DES.
//!
//! Production scale is defined by behavior under failure, not peak
//! algbw (*Collective Communication for 100k+ GPUs*, Si et al., devotes
//! as much text to fault reconfiguration as to routing). This module
//! turns the simulator into a chaos testbed in three layers:
//!
//! * [`spec`] — the **fault model**: [`FaultSpec`] processes (link-rate
//!   jitter, link/NIC degradation, link/NIC/node death) with MTBF/MTTR
//!   exponentials drawn from the seeded SplitMix64 stream
//!   ([`crate::util::rng`]), so a chaos timeline is a deterministic
//!   function of `(specs, horizon, seed)`. Concrete [`InjectedFault`]s
//!   lower to engine [`crate::sim::RateEvent`]s against nominal pool
//!   capacities — injection scales a target's capacity (0 = death),
//!   repair restores nominal.
//!
//! * the **DES integration** — [`crate::sim::run_with_events`] executes
//!   a task graph under the event timeline: the fair-share solver
//!   re-converges at each mutation timestamp, in-flight transfers over a
//!   dead resource fail at the fault instant, and transfers activating
//!   onto a dead route fail immediately (dslab-style event-driven
//!   mutation of the shared resource state). With an empty timeline it
//!   delegates to the plain engine, so the zero-fault chaos path is
//!   bit-identical to the fault-free one (`tests/prop_faults.rs`).
//!
//! * [`recovery`] + [`chaos`] — **recovery policies** and the step-loop
//!   harness. [`RecoveryPolicy::RerouteStripes`] folds the dead NIC's
//!   stripe share into the survivors through the existing
//!   [`crate::balancer::RuntimeBalancer`] (FlexLink's multipath striping
//!   is what makes this cheap — a ring has nowhere to reroute);
//!   [`RecoveryPolicy::ReLower`] aborts and recompiles the collective
//!   over the surviving ranks (NCCL abort+reinit style, priced by a
//!   reinit cost; node death shrinks the cluster);
//!   [`RecoveryPolicy::CheckpointRestart`] is the trainer-level
//!   baseline — wait out the repair, reload, and recompute the steps
//!   lost since the last checkpoint. Recovery is bidirectional: with
//!   elastic regrow on (`chaos.regrow`, default true) a repaired NIC
//!   stripe is reactivated and a repaired node rejoins the cluster once
//!   its repair instant passes, paying the same detection (+reinit)
//!   costs the shrink paid. [`chaos::run_chaos`] walks a training-step
//!   loop against one timeline per policy and reports time-to-recover
//!   and goodput vs fault-free (`repro chaos` on the CLI, EXPERIMENTS.md
//!   §Chaos); [`chaos::run_chaos_trainer`] drives the same loop through
//!   a bucketed-overlap trainer step (`repro chaos --trainer`) so TTR
//!   lands in loss-curve wall time.

pub mod chaos;
pub mod recovery;
pub mod spec;

pub use chaos::{
    run_chaos, run_chaos_trainer, ChaosOutcome, ChaosScenario, TrainerChaosSpec,
};
pub use recovery::{RecoveryPolicy, RecoverySpec};
pub use spec::{
    schedule, timeline_events, timeline_events_relabeled, FaultKind, FaultSpec, FaultTarget,
    InjectedFault, NodeRelabel,
};
