//! The chaos harness: a training-step loop driven against one fault
//! timeline, once per recovery policy.
//!
//! [`run_chaos`] walks virtual time step by step. Each step compiles the
//! collective over the *current* share state (and, after a `ReLower`
//! node shrink, the current surviving cluster), lowers the timeline's
//! still-relevant faults to engine rate events relative to the step's
//! start ([`super::timeline_events_relabeled`] — needles are rewritten
//! through the physical→dense [`super::NodeRelabel`] so a fault keeps
//! striking the node it was injected on after a shrink), and executes
//! under [`crate::sim::run_with_events`]. A clean step advances the
//! clock by its makespan; an aborted step hands the failure instant to
//! the recovery policy, which advances the clock by its own cost model
//! ([`super::RecoverySpec`]) and mutates the share / cluster state.
//! Because every policy replays the *same* timeline, the resulting
//! [`ChaosOutcome`]s compare goodput and time-to-recover apples to
//! apples (`repro chaos`, EXPERIMENTS.md §Chaos).
//!
//! Recovery is **bidirectional** (elastic regrow, on by default via
//! `chaos.regrow`): when a dead NIC's or node's repair instant passes,
//! `RerouteStripes` reactivates the stripe through
//! [`RuntimeBalancer::reactivate`] and `ReLower` regrows the shrunken
//! cluster back to full node count — each paying the same
//! detection (+reinit) costs its shrink paid — so a repaired resource
//! stops taxing goodput for the rest of the run.
//!
//! [`run_chaos_trainer`] drives the same loop through a *bucketed
//! overlap trainer step* (fwd compute → chunked bwd compute overlapped
//! with per-bucket gradient collectives, the PR-4 DDP shape) instead of
//! a bare collective, so TTR and degradation show up in loss-curve wall
//! time (`repro chaos --trainer`).
//!
//! With an empty timeline the loop reduces to `steps` identical
//! fault-free runs — `run_with_events` delegates to the plain engine, so
//! the zero-fault chaos path is bit-identical to `cc.run`
//! (`tests/prop_faults.rs` pins this against the golden traces).

use super::recovery::{RecoveryPolicy, RecoverySpec};
use super::spec::{timeline_events_relabeled, FaultSpec, InjectedFault, NodeRelabel};
use crate::balancer::shares::Shares;
use crate::balancer::tier::TierShares;
use crate::balancer::RuntimeBalancer;
use crate::collectives::hierarchical::{ClusterCollective, PricingMode};
use crate::collectives::CollectiveKind;
use crate::config::BalancerConfig;
use crate::links::calib::Calibration;
use crate::links::StripeId;
use crate::sim::{run_with_events, RateEvent, ResourcePool, SimTime, TaskGraph, TaskId};
use crate::topology::cluster::{Cluster, ClusterSpec};
use anyhow::{bail, Context, Result};

/// A named bundle of fault processes — the unit the `repro chaos` sweep
/// schedules and replays per policy.
#[derive(Debug, Clone)]
pub struct ChaosScenario {
    pub name: String,
    pub specs: Vec<FaultSpec>,
}

impl ChaosScenario {
    /// NIC deaths only, over every NIC of the cluster — the scenario the
    /// acceptance ordering (reroute ≻ relower ≻ ckpt) is stated on.
    pub fn nic_death(n_nodes: usize, n_nics: usize, mtbf_s: f64, mttr_s: f64) -> Self {
        ChaosScenario {
            name: "nic-death".into(),
            specs: vec![FaultSpec::any_nic_death(n_nodes, n_nics, mtbf_s, mttr_s)],
        }
    }

    /// NIC deaths plus non-fatal noise: sustained NVLink degradation and
    /// NIC rate jitter. The noise stretches steps without aborting them,
    /// so `degraded_steps` separates from `failures` in the report.
    pub fn mixed(n_nodes: usize, n_nics: usize, mtbf_s: f64, mttr_s: f64) -> Self {
        ChaosScenario {
            name: "mixed".into(),
            specs: vec![
                FaultSpec::any_nic_death(n_nodes, n_nics, mtbf_s, mttr_s),
                FaultSpec::link_degrade("node0.nvlink", 0.6, mtbf_s * 2.0, mttr_s),
                FaultSpec::link_jitter("nic.up", 0.7, 0.95, mtbf_s, mttr_s * 0.5),
            ],
        }
    }
}

/// A fixed two-fault timeline scaled to the fault-free step time `t0`:
/// one NIC death landing mid-step-3-ish and never repairing within the
/// run, one NVLink degradation window. Deterministic by construction
/// (no RNG), so `repro chaos --smoke` is stable across seeds — the CI
/// tier-1 smoke and the acceptance ordering test both use it.
pub fn smoke_timeline(t0: SimTime) -> Vec<InjectedFault> {
    let s = t0.as_secs_f64();
    vec![
        InjectedFault::nic_death(
            0,
            1,
            SimTime::from_secs_f64(s * 2.5),
            SimTime::from_secs_f64(s * 200.0),
        ),
        InjectedFault::degrade(
            "node1.nvlink",
            0.6,
            SimTime::from_secs_f64(s * 5.0),
            SimTime::from_secs_f64(s * 7.0),
        ),
    ]
}

/// A single NIC death whose repair lands *inside* the run (2.5·t0 →
/// 6.5·t0) — the deterministic elastic-regrow smoke. With `regrow` on,
/// the policies reactivate the stripe once the clock passes 6.5·t0 and
/// bank strictly higher goodput than a shrink-only replay of the same
/// timeline; `repro chaos --smoke` asserts exactly that (tier-1 CI).
pub fn smoke_repair_timeline(t0: SimTime) -> Vec<InjectedFault> {
    let s = t0.as_secs_f64();
    vec![InjectedFault::nic_death(
        0,
        1,
        SimTime::from_secs_f64(s * 2.5),
        SimTime::from_secs_f64(s * 6.5),
    )]
}

/// What one policy's replay of a timeline produced.
#[derive(Debug, Clone)]
pub struct ChaosOutcome {
    pub policy: RecoveryPolicy,
    pub msg_bytes: u64,
    /// Steps the trainer banked (always the requested count on success).
    pub steps: usize,
    /// Aborted collective attempts (one fault can abort several).
    pub failures: usize,
    /// Timeline entries whose injection fell inside the run's horizon.
    pub faults_injected: usize,
    /// Fault-instant → next-banked-step spans, one per outage.
    pub recoveries: Vec<SimTime>,
    /// Clean steps that still ran > 0.1% over the fault-free step time
    /// (degradation windows, post-recovery reduced stripe counts).
    pub degraded_steps: usize,
    /// Total virtual time to bank all steps.
    pub virtual_time: SimTime,
    /// Fault-free single-step makespan (the goodput baseline).
    pub fault_free_step: SimTime,
    /// Collective attempts, successful or aborted.
    pub attempts: usize,
    /// Elastic-regrow events: repaired stripes reactivated / nodes
    /// rejoined (0 when `regrow` is off or no repair landed in-run).
    pub regrows: usize,
    /// Share state at the end of the run — `inter.n_active()` back at
    /// the full stripe count is the observable regrow signature.
    pub final_tiers: TierShares,
    /// Makespan of the last banked step (fault-free again after a full
    /// regrow, still degraded under shrink-only recovery).
    pub last_step: SimTime,
}

impl ChaosOutcome {
    /// Banked training bytes per virtual second, in GB/s.
    pub fn goodput_gbps(&self) -> f64 {
        let s = self.virtual_time.as_secs_f64();
        if s <= 0.0 {
            return 0.0;
        }
        (self.steps as f64 * self.msg_bytes as f64) / s / 1e9
    }

    /// The same metric for a fault-free run (every step at `t0`).
    pub fn fault_free_gbps(&self) -> f64 {
        let s = self.fault_free_step.as_secs_f64();
        if s <= 0.0 {
            return 0.0;
        }
        self.msg_bytes as f64 / s / 1e9
    }

    /// Goodput as a fraction of fault-free (1.0 = no loss).
    pub fn goodput_ratio(&self) -> f64 {
        let ff = self.fault_free_gbps();
        if ff <= 0.0 {
            return 0.0;
        }
        self.goodput_gbps() / ff
    }

    /// Mean time-to-recover across outages; `None` if none occurred.
    /// Rounds to nearest instead of truncating — at the engine's tick
    /// granularity flooring systematically under-reported the mean.
    pub fn mean_ttr(&self) -> Option<SimTime> {
        if self.recoveries.is_empty() {
            return None;
        }
        let n = self.recoveries.len() as u64;
        let sum: u64 = self.recoveries.iter().map(|t| t.0).sum();
        Some(SimTime((sum + n / 2) / n))
    }
}

/// The compute shape of one [`run_chaos_trainer`] step: forward pass,
/// backward pass chunked into `buckets` gradient buckets, each bucket's
/// collective overlapped with the remaining backward compute on the
/// shared DES — the PR-4 DDP shape, rebuilt directly on the task graph
/// so it can run under a fault timeline.
#[derive(Debug, Clone, Copy)]
pub struct TrainerChaosSpec {
    /// Forward-pass compute time per step.
    pub fwd: SimTime,
    /// Backward-pass compute time per step (split evenly over buckets).
    pub bwd: SimTime,
    /// Gradient buckets (overlap granularity, ≥ 1).
    pub buckets: usize,
}

impl TrainerChaosSpec {
    /// Derive compute times from the gradient message the trainer's
    /// convention way: `params = msg_bytes / 4` (f32 gradients), fwd =
    /// 2·P·T flops, bwd = 4·P·T flops over the effective GPU rate —
    /// mirroring [`crate::trainer`]'s `compute_times`.
    pub fn from_message(msg_bytes: u64, gpu_tflops: f64, tokens: usize, buckets: usize) -> Self {
        assert!(gpu_tflops > 0.0, "gpu_tflops must be > 0");
        let params = (msg_bytes / 4).max(1) as f64;
        let t = tokens as f64;
        let rate = gpu_tflops * 1e12;
        TrainerChaosSpec {
            fwd: SimTime::from_secs_f64(2.0 * params * t / rate),
            bwd: SimTime::from_secs_f64(4.0 * params * t / rate),
            buckets: buckets.max(1),
        }
    }
}

/// What one trainer-shaped step produced (the trainer-workload analogue
/// of [`crate::collectives::hierarchical::FaultedHierRun`]).
struct TrainerStepRun {
    ok: bool,
    total: SimTime,
    first_failure: Option<SimTime>,
    inter_times: Vec<(StripeId, SimTime)>,
}

/// Compile and run ONE bucketed-overlap trainer step under a fault
/// timeline: fwd delay → per-bucket (bwd-chunk delay ‖ gradient
/// collective), comm buckets FIFO-ordered behind each other and gated on
/// their producing compute chunk — all on one task graph so compute and
/// communication contend (and fail) on the same DES clock.
fn run_trainer_step(
    cc: &ClusterCollective<'_>,
    pool: ResourcePool,
    msg_bytes: u64,
    tiers: &TierShares,
    spec: &TrainerChaosSpec,
    events: &[RateEvent],
) -> Result<TrainerStepRun> {
    anyhow::ensure!(
        msg_bytes >= 4 && msg_bytes % 4 == 0,
        "gradient message must be 4-byte (f32) aligned"
    );
    let buckets = spec.buckets.clamp(1, (msg_bytes / 4) as usize);
    let chunk = SimTime::from_secs_f64(spec.bwd.as_secs_f64() / buckets as f64);
    let mut pool = pool;
    let mut graph = TaskGraph::new();
    let mut prev_compute = graph.delay(spec.fwd, vec![]);
    let mut prev_comm: Option<TaskId> = None;
    for b in 0..buckets as u64 {
        prev_compute = graph.delay(chunk, vec![prev_compute]);
        // Element-aligned bucket extents covering the message exactly.
        let lo = msg_bytes * b / buckets as u64 / 4 * 4;
        let hi = if b + 1 == buckets as u64 {
            msg_bytes
        } else {
            msg_bytes * (b + 1) / buckets as u64 / 4 * 4
        };
        if hi <= lo {
            continue;
        }
        let base = graph.len();
        let compiled = cc.compile_onto(hi - lo, tiers, 4, pool, graph)?;
        pool = compiled.pool;
        graph = compiled.graph;
        // The bucket's collective starts once its gradients exist (the
        // bwd chunk) and its stream predecessor finished (comm FIFO).
        let mut deps = vec![prev_compute];
        if let Some(pc) = prev_comm {
            deps.push(pc);
        }
        let end = graph.len();
        graph.gate_roots_in(base..end, &deps);
        let sinks = graph.sinks_in(base..end);
        prev_comm = Some(graph.barrier(sinks));
    }
    let run = run_with_events(pool, &graph, events)?;
    let inter_times = tiers
        .inter
        .active_paths()
        .into_iter()
        .filter_map(|s| run.schedule.tag_finish(&graph, s.tag()).map(|t| (s, t)))
        .collect();
    Ok(TrainerStepRun {
        ok: run.failed.is_empty(),
        total: run.schedule.makespan,
        first_failure: run.first_failure,
        inter_times,
    })
}

/// What the chaos loop prices per step: a bare collective (the original
/// harness) or a full bucketed-overlap trainer step.
enum Workload<'a> {
    Collective,
    Trainer(&'a TrainerChaosSpec),
}

/// First active stripe that is not itself a culprit of the current
/// outage — the fold target for stripe surgery. With two simultaneous
/// NIC deaths the old "any stripe ≠ the one being dropped" rule could
/// pick the *other dying* stripe; excluding all culprits guarantees the
/// share lands on a survivor. `None` when no survivor exists.
fn fold_target(shares: &Shares<StripeId>, culprits: &[StripeId]) -> Option<StripeId> {
    shares
        .active_paths()
        .into_iter()
        .find(|s| !culprits.contains(s))
}

/// Replay `timeline` through a `steps`-step training loop under one
/// recovery policy. See the module docs for the step/recovery/regrow
/// state machine; the policy-specific handling is inline below.
#[allow(clippy::too_many_arguments)]
pub fn run_chaos(
    cluster: &Cluster,
    calib: Calibration,
    kind: CollectiveKind,
    msg_bytes: u64,
    steps: usize,
    timeline: &[InjectedFault],
    rec: &RecoverySpec,
    cfg: &BalancerConfig,
) -> Result<ChaosOutcome> {
    run_chaos_impl(
        cluster,
        calib,
        kind,
        msg_bytes,
        steps,
        timeline,
        rec,
        cfg,
        Workload::Collective,
    )
}

/// As [`run_chaos`], but each step is a full bucketed-overlap trainer
/// step ([`TrainerChaosSpec`]) instead of a bare collective: recovery
/// spans and degradation land in loss-curve wall time, where compute
/// overlap partially hides communication slowdowns
/// (`repro chaos --trainer`).
#[allow(clippy::too_many_arguments)]
pub fn run_chaos_trainer(
    cluster: &Cluster,
    calib: Calibration,
    kind: CollectiveKind,
    msg_bytes: u64,
    steps: usize,
    timeline: &[InjectedFault],
    rec: &RecoverySpec,
    cfg: &BalancerConfig,
    tspec: &TrainerChaosSpec,
) -> Result<ChaosOutcome> {
    run_chaos_impl(
        cluster,
        calib,
        kind,
        msg_bytes,
        steps,
        timeline,
        rec,
        cfg,
        Workload::Trainer(tspec),
    )
}

#[allow(clippy::too_many_arguments)]
fn run_chaos_impl(
    cluster: &Cluster,
    calib: Calibration,
    kind: CollectiveKind,
    msg_bytes: u64,
    steps: usize,
    timeline: &[InjectedFault],
    rec: &RecoverySpec,
    cfg: &BalancerConfig,
    workload: Workload<'_>,
) -> Result<ChaosOutcome> {
    anyhow::ensure!(
        cluster.n_nodes() >= 2,
        "chaos runs price multi-node clusters (n_nodes >= 2)"
    );
    anyhow::ensure!(steps > 0, "need at least one step");
    let nl = cluster.gpus_per_node();
    let tiers0 = TierShares::new(Shares::nvlink_only(), nl);
    // Fault-free reference step (also the zero-fault bit-identity anchor:
    // with an empty timeline every loop step takes exactly this path).
    // Auto pricing: exact per-chunk graphs below the fold threshold
    // (bit-identical to the pre-fold chaos loop at smoke scale),
    // partial-symmetry-folded at scale so between-fault steps — and the
    // fault-free reference — stay sublinear on big clusters.
    let t0 = match &workload {
        Workload::Collective => ClusterCollective::new(cluster, calib.clone(), kind, nl)
            .with_pricing(PricingMode::Auto)
            .run(msg_bytes, &tiers0, 4)?
            .total,
        Workload::Trainer(spec) => {
            let cc = ClusterCollective::new(cluster, calib.clone(), kind, nl);
            let run =
                run_trainer_step(&cc, cluster.pool.clone(), msg_bytes, &tiers0, spec, &[])?;
            anyhow::ensure!(run.ok, "fault-free trainer step failed");
            run.total
        }
    };
    anyhow::ensure!(t0 > SimTime::ZERO, "degenerate fault-free step");
    let degraded_floor = SimTime::from_secs_f64(t0.as_secs_f64() * 1.001);

    let mut current = tiers0.clone();
    let mut inter_rb = RuntimeBalancer::with_preferred(cfg.clone(), tiers0.inter.clone(), None);
    // `ReLower` node death swaps in a shrunken cluster; all collective
    // borrows stay inside the per-step scope below so the swap is legal.
    let mut shrunk: Option<Cluster> = None;
    // Physical→dense node map: `ReLower` shrinks relabel survivors, so
    // timeline needles must be rewritten or a fault addressed to the
    // dead node would strike whoever inherited its dense name.
    let mut relabel = NodeRelabel::identity(cluster.n_nodes());
    // Outstanding shrinkage awaiting repair: (stripe | physical node,
    // repair instant). Drained by the regrow pass when the clock passes
    // a repair; only populated by policies that actually shrink.
    let mut dead_stripes: Vec<(StripeId, SimTime)> = Vec::new();
    let mut dead_nodes: Vec<(usize, SimTime)> = Vec::new();
    let mut now = SimTime::ZERO;
    let mut completed = 0usize;
    let mut failures = 0usize;
    let mut degraded = 0usize;
    // Degraded flag per banked step, so a checkpoint rollback can also
    // roll back the degraded-step count (the recomputed steps would
    // otherwise be counted as degraded twice).
    let mut banked: Vec<bool> = Vec::new();
    let mut recoveries: Vec<SimTime> = Vec::new();
    let mut pending_fail: Option<SimTime> = None;
    let mut attempts = 0usize;
    let mut regrows = 0usize;
    let mut last_step = SimTime::ZERO;
    // Every abort either removes a fault's route from the lowering or
    // advances the clock past its repair, so the loop terminates; the
    // guard turns a modeling bug into an error instead of a hang.
    let max_attempts = steps * 8 + 64;

    while completed < steps {
        attempts += 1;
        if attempts > max_attempts {
            bail!(
                "chaos loop did not converge after {max_attempts} attempts \
                 ({completed}/{steps} steps banked)"
            );
        }

        // Elastic regrow: repair events reactivate what death
        // deactivated, at the same detection (+reinit) costs the shrink
        // paid. Shrink-only mode (`--no-regrow`) skips this entirely.
        if rec.regrow {
            let mut i = 0;
            while i < dead_stripes.len() {
                if dead_stripes[i].1 > now {
                    i += 1;
                    continue;
                }
                let (s, _) = dead_stripes.remove(i);
                match rec.policy {
                    RecoveryPolicy::RerouteStripes => {
                        if inter_rb.reactivate(s) > 0.0 {
                            current.inter = inter_rb.shares().clone();
                            now = now + rec.detection;
                            regrows += 1;
                        }
                    }
                    RecoveryPolicy::ReLower => {
                        current = current.with_stripe(s);
                        inter_rb = RuntimeBalancer::with_preferred(
                            cfg.clone(),
                            current.inter.clone(),
                            None,
                        );
                        now = now + rec.detection + rec.reinit;
                        regrows += 1;
                    }
                    RecoveryPolicy::CheckpointRestart => {}
                }
            }
            let mut j = 0;
            while j < dead_nodes.len() {
                if dead_nodes[j].1 > now {
                    j += 1;
                    continue;
                }
                let (p, _) = dead_nodes.remove(j);
                relabel.revive(p);
                let alive = relabel.n_alive();
                // Back at full strength → drop the shrunken stand-in
                // entirely (bit-identical full-cluster pricing again).
                shrunk = if alive == cluster.n_nodes() {
                    None
                } else {
                    Some(Cluster::build(&ClusterSpec::new(
                        alive,
                        cluster.spec.node.clone(),
                    )))
                };
                inter_rb = RuntimeBalancer::with_preferred(
                    cfg.clone(),
                    current.inter.clone(),
                    None,
                );
                now = now + rec.detection + rec.reinit;
                regrows += 1;
            }
        }

        let (ok, dt, first_failure, inter_times) = {
            let active: &Cluster = shrunk.as_ref().unwrap_or(cluster);
            let cc = ClusterCollective::new(active, calib.clone(), kind, nl)
                .with_pricing(PricingMode::Auto);
            let events = timeline_events_relabeled(timeline, &active.pool, now, &relabel);
            match &workload {
                Workload::Collective => {
                    let run = cc.run_under_faults(msg_bytes, &current, 4, &events)?;
                    (
                        run.ok(),
                        run.report.total,
                        run.first_failure,
                        run.report.inter_times.clone(),
                    )
                }
                Workload::Trainer(spec) => {
                    let run = run_trainer_step(
                        &cc,
                        active.pool.clone(),
                        msg_bytes,
                        &current,
                        spec,
                        &events,
                    )?;
                    (run.ok, run.total, run.first_failure, run.inter_times)
                }
            }
        };

        if ok {
            now = now + dt;
            completed += 1;
            last_step = dt;
            let is_degraded = dt > degraded_floor;
            banked.push(is_degraded);
            if is_degraded {
                degraded += 1;
            }
            if let Some(tf) = pending_fail.take() {
                recoveries.push(now.saturating_sub(tf));
            }
            // Only RerouteStripes keeps adapting between faults — the
            // stage-2 balancer equalizes the surviving stripes. ReLower
            // trusts its recompiled distribution; CheckpointRestart has
            // no communication-layer agency at all.
            if rec.policy == RecoveryPolicy::RerouteStripes
                && inter_rb.observe(inter_times).is_some()
            {
                current.inter = inter_rb.shares().clone();
            }
            continue;
        }

        // Aborted step: no bytes banked, clock moves to the failure
        // instant and then by the policy's recovery cost.
        failures += 1;
        let tf_abs = now + first_failure.context("failed run lacks first_failure")?;
        pending_fail.get_or_insert(tf_abs);
        let culprits: Vec<&InjectedFault> = timeline
            .iter()
            .filter(|f| f.is_death() && f.at <= tf_abs && tf_abs < f.until)
            .collect();
        // Every culprit stripe of this outage, so the fold-target search
        // can exclude all of them (not just the one being dropped).
        let culprit_stripes: Vec<StripeId> = culprits
            .iter()
            .filter_map(|f| f.target.stripe.map(StripeId))
            .collect();

        match rec.policy {
            RecoveryPolicy::RerouteStripes => {
                now = tf_abs + rec.detection;
                for f in &culprits {
                    if let Some(s) = f.target.stripe {
                        let dead = StripeId(s);
                        let into = fold_target(inter_rb.shares(), &culprit_stripes)
                            .context("no surviving NIC stripe to reroute onto")?;
                        if inter_rb.force_deactivate(dead, into) > 0.0 {
                            current.inter = inter_rb.shares().clone();
                            dead_stripes.push((dead, f.until));
                        }
                    } else if f.target.node.is_some() {
                        bail!(
                            "RerouteStripes cannot survive node death — \
                             use the relower or ckpt policy"
                        );
                    } else {
                        // A dead link with no modeled alternative (e.g.
                        // an NVLink lane): nothing to reroute onto, so
                        // the policy degrades to waiting out the repair.
                        now = now.max(f.until);
                    }
                }
            }
            RecoveryPolicy::ReLower => {
                now = tf_abs + rec.detection + rec.reinit;
                for f in &culprits {
                    if let Some(s) = f.target.stripe {
                        let dead = StripeId(s);
                        if current.inter.is_active(dead) {
                            let into = fold_target(&current.inter, &culprit_stripes)
                                .context("no surviving NIC stripe to re-lower over")?;
                            current.inter.deactivate(dead, into);
                            dead_stripes.push((dead, f.until));
                        }
                    } else if let Some(p) = f.target.node {
                        relabel.retire(p);
                        let alive = relabel.n_alive();
                        anyhow::ensure!(
                            alive >= 2,
                            "cannot re-lower below 2 nodes (node death left {alive} alive)"
                        );
                        // Survivors are relabeled densely (node k's
                        // resources renamed) — a modeling artifact that
                        // keeps the topology builder unchanged; `relabel`
                        // rewrites later timeline needles accordingly.
                        // With `regrow` on, the repaired node rejoins
                        // once the clock passes its repair instant.
                        shrunk = Some(Cluster::build(&ClusterSpec::new(
                            alive,
                            cluster.spec.node.clone(),
                        )));
                        dead_nodes.push((p, f.until));
                    } else {
                        now = now.max(f.until);
                    }
                }
                // Reinit wipes runtime balancer state along with the
                // communicator.
                inter_rb =
                    RuntimeBalancer::with_preferred(cfg.clone(), current.inter.clone(), None);
            }
            RecoveryPolicy::CheckpointRestart => {
                // The trainer has no comm-layer agency: wait until the
                // hardware is repaired, reload the checkpoint, recompute
                // everything since the last checkpoint boundary. The
                // lost steps naturally re-run through the loop,
                // consuming virtual time a second time.
                let repair = culprits.iter().map(|f| f.until).max().unwrap_or(tf_abs);
                now = (tf_abs + rec.detection).max(repair) + rec.reload;
                let lost = completed % rec.ckpt_interval.max(1);
                // Roll back the degraded count with the banked steps —
                // the recomputed steps re-run through the loop and must
                // not be counted as degraded twice.
                for _ in 0..lost {
                    if banked.pop().unwrap_or(false) {
                        degraded -= 1;
                    }
                }
                completed -= lost;
            }
        }
    }

    let faults_injected = timeline.iter().filter(|f| f.at < now).count();
    Ok(ChaosOutcome {
        policy: rec.policy,
        msg_bytes,
        steps: completed,
        failures,
        faults_injected,
        recoveries,
        degraded_steps: degraded,
        virtual_time: now,
        fault_free_step: t0,
        attempts,
        regrows,
        final_tiers: current,
        last_step,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::Preset;
    use crate::config::ChaosConfig;

    fn cluster(nn: usize) -> Cluster {
        Cluster::build(&ClusterSpec::new(nn, Preset::H800.spec()))
    }

    fn rec(policy: RecoveryPolicy) -> RecoverySpec {
        RecoverySpec::from_config(policy, &ChaosConfig::default())
    }

    const MSG: u64 = 1 << 20;

    #[test]
    fn empty_timeline_runs_all_steps_at_fault_free_time() {
        let c = cluster(2);
        let out = run_chaos(
            &c,
            Calibration::h800(),
            CollectiveKind::AllReduce,
            MSG,
            4,
            &[],
            &rec(RecoveryPolicy::RerouteStripes),
            &BalancerConfig::default(),
        )
        .unwrap();
        assert_eq!(out.steps, 4);
        assert_eq!(out.failures, 0);
        assert_eq!(out.degraded_steps, 0);
        assert_eq!(out.attempts, 4);
        assert!(out.recoveries.is_empty());
        assert_eq!(out.virtual_time, SimTime(out.fault_free_step.0 * 4));
        assert!((out.goodput_ratio() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn nic_death_reroute_recovers_and_degrades() {
        let c = cluster(2);
        let t0 = ClusterCollective::new(&c, Calibration::h800(), CollectiveKind::AllReduce, 8)
            .run(MSG, &TierShares::new(Shares::nvlink_only(), 8), 4)
            .unwrap()
            .total;
        let tl = smoke_timeline(t0);
        let out = run_chaos(
            &c,
            Calibration::h800(),
            CollectiveKind::AllReduce,
            MSG,
            6,
            &tl,
            &rec(RecoveryPolicy::RerouteStripes),
            &BalancerConfig::default(),
        )
        .unwrap();
        assert_eq!(out.steps, 6);
        assert!(out.failures >= 1, "the NIC death aborts at least one step");
        assert_eq!(out.recoveries.len(), 1, "one outage, one recovery span");
        assert!(out.mean_ttr().unwrap() > SimTime::ZERO);
        // Post-reroute steps run on 7 stripes → slower than fault-free.
        assert!(out.degraded_steps >= 1);
        assert!(out.goodput_ratio() < 1.0);
        // Loose floor: the 1 ms default detection latency dwarfs a 1 MiB
        // step time, so the ratio is dominated by the single outage.
        assert!(out.goodput_ratio() > 0.02, "reroute keeps real goodput");
    }

    #[test]
    fn node_death_relower_shrinks_cluster_and_ckpt_waits() {
        let c = cluster(3);
        let t0 = ClusterCollective::new(&c, Calibration::h800(), CollectiveKind::AllReduce, 8)
            .run(MSG, &TierShares::new(Shares::nvlink_only(), 8), 4)
            .unwrap()
            .total;
        let s = t0.as_secs_f64();
        let tl = vec![InjectedFault::node_death(
            2,
            SimTime::from_secs_f64(s * 1.5),
            SimTime::from_secs_f64(s * 40.0),
        )];
        let relower = run_chaos(
            &c,
            Calibration::h800(),
            CollectiveKind::AllReduce,
            MSG,
            5,
            &tl,
            &rec(RecoveryPolicy::ReLower),
            &BalancerConfig::default(),
        )
        .unwrap();
        assert_eq!(relower.steps, 5);
        assert!(relower.failures >= 1);
        // Recompiled over 2 survivors: the loop finished without the dead
        // node, and the post-shrink steps are degraded vs 3-node t0 only
        // if slower — either way the run converged, which is the point.
        let ckpt = run_chaos(
            &c,
            Calibration::h800(),
            CollectiveKind::AllReduce,
            MSG,
            5,
            &tl,
            &rec(RecoveryPolicy::CheckpointRestart),
            &BalancerConfig::default(),
        )
        .unwrap();
        assert!(ckpt.failures >= 1);
        // Ckpt waits out the ~40·t0 repair; relower pays only
        // detection + reinit and recompiles.
        assert!(
            relower.virtual_time < ckpt.virtual_time,
            "relower {:?} should beat ckpt {:?}",
            relower.virtual_time,
            ckpt.virtual_time
        );
        assert!(relower.goodput_gbps() > ckpt.goodput_gbps());
    }

    #[test]
    fn reroute_rejects_node_death() {
        let c = cluster(2);
        let tl = vec![InjectedFault::node_death(
            1,
            SimTime::from_secs_f64(1e-6),
            SimTime::from_secs_f64(1e3),
        )];
        let err = run_chaos(
            &c,
            Calibration::h800(),
            CollectiveKind::AllReduce,
            MSG,
            2,
            &tl,
            &rec(RecoveryPolicy::RerouteStripes),
            &BalancerConfig::default(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("node death"));
    }
}
