//! Recovery policies and their cost knobs.
//!
//! A death fault aborts the in-flight collective; what happens next — and
//! what it costs — is the policy:
//!
//! * [`RecoveryPolicy::RerouteStripes`] — pay only *detection*: fold the
//!   dead NIC's stripe share into the survivors through the runtime
//!   balancer and keep going with the same compiled structure. FlexLink's
//!   multipath striping makes this the cheap path — a plain ring has no
//!   second stripe to reroute onto.
//! * [`RecoveryPolicy::ReLower`] — pay detection + *reinit*: abort the
//!   communicator and recompile the collective over the surviving ranks
//!   (NCCL abort+reinit style). Handles node death, which pure stripe
//!   rerouting cannot.
//! * [`RecoveryPolicy::CheckpointRestart`] — the trainer-level baseline:
//!   wait out the repair, pay *reload*, and recompute every step since
//!   the last checkpoint. No communication-layer intelligence at all.
//!
//! The cost knobs live in [`RecoverySpec`] and come from
//! `[chaos]` config ([`crate::config::ChaosConfig`]).

use crate::config::ChaosConfig;
use crate::sim::SimTime;
use std::fmt;
use std::str::FromStr;

/// What the system does after a death fault aborts a collective.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryPolicy {
    /// Rebalance stripe shares off the dead NIC (comm-layer, no reinit).
    RerouteStripes,
    /// Abort + recompile over surviving ranks (comm-layer, pays reinit).
    ReLower,
    /// Wait out repair, reload checkpoint, recompute lost steps.
    CheckpointRestart,
}

impl RecoveryPolicy {
    /// All policies, in cheapest-first order (the `repro chaos` sweep
    /// compares them over one shared timeline).
    pub const ALL: [RecoveryPolicy; 3] = [
        RecoveryPolicy::RerouteStripes,
        RecoveryPolicy::ReLower,
        RecoveryPolicy::CheckpointRestart,
    ];
}

impl FromStr for RecoveryPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "reroute" | "reroute_stripes" => Ok(RecoveryPolicy::RerouteStripes),
            "relower" | "re_lower" => Ok(RecoveryPolicy::ReLower),
            "ckpt" | "checkpoint" | "checkpoint_restart" => Ok(RecoveryPolicy::CheckpointRestart),
            other => Err(format!(
                "unknown recovery policy '{other}' (expected reroute|relower|ckpt)"
            )),
        }
    }
}

impl fmt::Display for RecoveryPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            RecoveryPolicy::RerouteStripes => "reroute",
            RecoveryPolicy::ReLower => "relower",
            RecoveryPolicy::CheckpointRestart => "ckpt",
        })
    }
}

/// A policy plus its cost model.
#[derive(Debug, Clone)]
pub struct RecoverySpec {
    pub policy: RecoveryPolicy,
    /// Time from fault instant to the system *noticing* (health-check /
    /// timeout latency). Every policy pays it.
    pub detection: SimTime,
    /// Communicator teardown + re-setup cost (`ReLower` only).
    pub reinit: SimTime,
    /// Steps between trainer checkpoints (`CheckpointRestart`: everything
    /// since the last multiple is recomputed).
    pub ckpt_interval: usize,
    /// Checkpoint reload cost (`CheckpointRestart` only).
    pub reload: SimTime,
    /// Elastic regrow: when a dead NIC's or node's repair instant passes,
    /// reactivate the stripe ([`RecoveryPolicy::RerouteStripes`]) or
    /// regrow the shrunken cluster to full node count
    /// ([`RecoveryPolicy::ReLower`]), paying the same detection (+reinit
    /// for relower) costs the shrink paid. Off → the pre-regrow
    /// shrink-only behavior ([`RecoveryPolicy::CheckpointRestart`] never
    /// shrinks, so the knob is inert there).
    pub regrow: bool,
}

impl RecoverySpec {
    /// Bind a policy to the `[chaos]` config's cost knobs.
    pub fn from_config(policy: RecoveryPolicy, cfg: &ChaosConfig) -> Self {
        RecoverySpec {
            policy,
            detection: SimTime::from_secs_f64(cfg.detection_us * 1e-6),
            reinit: SimTime::from_secs_f64(cfg.reinit_ms * 1e-3),
            ckpt_interval: cfg.ckpt_interval.max(1),
            reload: SimTime::from_secs_f64(cfg.reload_s),
            regrow: cfg.regrow,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse_display_roundtrip() {
        for p in RecoveryPolicy::ALL {
            assert_eq!(p.to_string().parse::<RecoveryPolicy>().unwrap(), p);
        }
        assert_eq!(
            "reroute_stripes".parse::<RecoveryPolicy>().unwrap(),
            RecoveryPolicy::RerouteStripes
        );
        assert_eq!(
            "CHECKPOINT".parse::<RecoveryPolicy>().unwrap(),
            RecoveryPolicy::CheckpointRestart
        );
        assert!("raid".parse::<RecoveryPolicy>().is_err());
    }

    #[test]
    fn spec_from_config_converts_units() {
        let cfg = ChaosConfig::default();
        let spec = RecoverySpec::from_config(RecoveryPolicy::ReLower, &cfg);
        assert_eq!(spec.policy, RecoveryPolicy::ReLower);
        assert!((spec.detection.as_secs_f64() - cfg.detection_us * 1e-6).abs() < 1e-12);
        assert!((spec.reinit.as_secs_f64() - cfg.reinit_ms * 1e-3).abs() < 1e-9);
        assert!(spec.ckpt_interval >= 1);
        assert!(spec.regrow, "elastic regrow defaults on");
    }
}
