//! The fault model: stochastic fault processes and their deterministic
//! lowering to engine rate events.
//!
//! A [`FaultSpec`] is one fault *process* — which resources it can hit,
//! what it does to them ([`FaultKind`]), and its MTBF/MTTR exponentials.
//! [`schedule`] draws a concrete timeline of [`InjectedFault`]s from the
//! seeded SplitMix64 stream (one independent substream per spec, so
//! adding a process never perturbs another's draws), and
//! [`timeline_events`] lowers the timeline to the sorted
//! [`RateEvent`]s [`crate::sim::run_with_events`] consumes: injection
//! scales each target resource to `factor × nominal` (0 = death), repair
//! restores nominal capacity.
//!
//! Simplifications, stated rather than hidden: repairs restore *nominal*
//! capacity, so when two faults overlap on one resource the earliest
//! repair already restores it (last event wins). Since the elastic-regrow
//! work, repair instants also feed the recovery layer: when `regrow` is
//! on (the default), [`crate::faults::run_chaos`] re-activates dropped
//! stripes and re-grows shrunken clusters once the corresponding fault's
//! `until` passes — see [`crate::faults::chaos`]. After a `ReLower` node
//! shrink, survivors are densely relabeled, so timeline needles must be
//! rewritten through the physical→dense [`NodeRelabel`] map
//! ([`timeline_events_relabeled`]) or a fault addressed to the dead node
//! would strike the survivor that inherited its name.

use crate::sim::{RateEvent, ResourcePool, SimTime};
use crate::util::rng::Rng;

/// What a fault does to its target resources while active.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Transient rate jitter (straggler links): capacity scaled by a
    /// factor drawn uniformly from `[lo, hi)` per event.
    RateJitter { lo: f64, hi: f64 },
    /// Sustained degradation to `factor × nominal` (0 < factor < 1) —
    /// a flapping NIC, a downtrained PCIe lane.
    Degrade { factor: f64 },
    /// Hard death: capacity → 0 until repair. In-flight transfers over
    /// the target fail (the engine marks their tasks failed) and the
    /// collective aborts — recovery is the policy layer's job.
    Death,
}

/// The resource set one fault event hits, plus what the recovery layer
/// needs to know about it (which NIC stripe it disables, which node it
/// takes down). Needles are pool-name substrings resolved at lowering
/// time, so one target can cover both directions of a NIC.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultTarget {
    /// Pool-name substrings zeroed/scaled together.
    pub needles: Vec<String>,
    /// NIC stripe this target disables, when it is a NIC — drives the
    /// `RerouteStripes` / `ReLower` stripe surgery.
    pub stripe: Option<u32>,
    /// Node index this target kills entirely, when it is a node —
    /// drives communicator shrink under `ReLower`.
    pub node: Option<usize>,
}

impl FaultTarget {
    /// Both directions of NIC `nic` on node `node` (the per-GPU NIC of
    /// the H800 topology: `node{k}.nic.{up,down}.gpu{g}`).
    pub fn nic(node: usize, nic: usize) -> Self {
        FaultTarget {
            needles: vec![
                format!("node{node}.nic.up.gpu{nic}"),
                format!("node{node}.nic.down.gpu{nic}"),
            ],
            stripe: Some(nic as u32),
            node: None,
        }
    }

    /// Every resource of node `node` (NVLink, PCIe, NICs, host memory).
    pub fn node(node: usize) -> Self {
        FaultTarget {
            needles: vec![format!("node{node}.")],
            stripe: None,
            node: Some(node),
        }
    }

    /// An arbitrary link set by name substring (e.g. `"node1.nvlink"`).
    pub fn link(needle: impl Into<String>) -> Self {
        FaultTarget {
            needles: vec![needle.into()],
            stripe: None,
            node: None,
        }
    }
}

/// One fault process: candidate targets (each event draws one
/// uniformly), the fault kind, and MTBF/MTTR means in sim-seconds.
#[derive(Debug, Clone)]
pub struct FaultSpec {
    /// Label for reports ("nic-death", "nvlink-jitter", ...).
    pub name: String,
    pub kind: FaultKind,
    pub targets: Vec<FaultTarget>,
    /// Mean time between failures (exponential inter-arrival), seconds.
    pub mtbf_s: f64,
    /// Mean time to repair (exponential duration), seconds.
    pub mttr_s: f64,
}

impl FaultSpec {
    pub fn new(
        name: impl Into<String>,
        kind: FaultKind,
        targets: Vec<FaultTarget>,
        mtbf_s: f64,
        mttr_s: f64,
    ) -> Self {
        assert!(mtbf_s > 0.0 && mtbf_s.is_finite(), "MTBF must be positive");
        assert!(mttr_s > 0.0 && mttr_s.is_finite(), "MTTR must be positive");
        assert!(!targets.is_empty(), "fault spec needs at least one target");
        if let FaultKind::Degrade { factor } = kind {
            assert!((0.0..1.0).contains(&factor), "degrade factor in (0, 1)");
            assert!(factor > 0.0, "factor 0 is Death, not Degrade");
        }
        if let FaultKind::RateJitter { lo, hi } = kind {
            assert!(0.0 < lo && lo <= hi && hi <= 1.0, "jitter range in (0, 1]");
        }
        FaultSpec {
            name: name.into(),
            kind,
            targets,
            mtbf_s,
            mttr_s,
        }
    }

    /// Death process over every NIC of an `n_nodes × n_nics` cluster.
    pub fn any_nic_death(n_nodes: usize, n_nics: usize, mtbf_s: f64, mttr_s: f64) -> Self {
        let targets = (0..n_nodes)
            .flat_map(|k| (0..n_nics).map(move |g| FaultTarget::nic(k, g)))
            .collect();
        FaultSpec::new("nic-death", FaultKind::Death, targets, mtbf_s, mttr_s)
    }

    /// Death process over whole nodes.
    pub fn any_node_death(n_nodes: usize, mtbf_s: f64, mttr_s: f64) -> Self {
        let targets = (0..n_nodes).map(FaultTarget::node).collect();
        FaultSpec::new("node-death", FaultKind::Death, targets, mtbf_s, mttr_s)
    }

    /// Sustained degradation on a named link set.
    pub fn link_degrade(needle: &str, factor: f64, mtbf_s: f64, mttr_s: f64) -> Self {
        FaultSpec::new(
            format!("degrade:{needle}"),
            FaultKind::Degrade { factor },
            vec![FaultTarget::link(needle)],
            mtbf_s,
            mttr_s,
        )
    }

    /// Transient rate jitter on a named link set.
    pub fn link_jitter(needle: &str, lo: f64, hi: f64, mtbf_s: f64, mttr_s: f64) -> Self {
        FaultSpec::new(
            format!("jitter:{needle}"),
            FaultKind::RateJitter { lo, hi },
            vec![FaultTarget::link(needle)],
            mtbf_s,
            mttr_s,
        )
    }
}

/// One concrete injected fault: absolute injection/repair times, the
/// drawn target, and the resolved capacity factor (0 = death).
#[derive(Debug, Clone)]
pub struct InjectedFault {
    /// Name of the spec that drew it (or a label for hand-built faults).
    pub spec: String,
    pub kind: FaultKind,
    pub at: SimTime,
    pub until: SimTime,
    pub target: FaultTarget,
    /// Capacity multiplier vs nominal during `[at, until)`.
    pub factor: f64,
}

impl InjectedFault {
    /// True when this fault zeroes its targets (aborts collectives).
    pub fn is_death(&self) -> bool {
        self.factor <= 0.0
    }

    /// Hand-built NIC death (deterministic scenarios, smoke tests).
    pub fn nic_death(node: usize, nic: usize, at: SimTime, until: SimTime) -> Self {
        assert!(at < until);
        InjectedFault {
            spec: "nic-death".into(),
            kind: FaultKind::Death,
            at,
            until,
            target: FaultTarget::nic(node, nic),
            factor: 0.0,
        }
    }

    /// Hand-built node death.
    pub fn node_death(node: usize, at: SimTime, until: SimTime) -> Self {
        assert!(at < until);
        InjectedFault {
            spec: "node-death".into(),
            kind: FaultKind::Death,
            at,
            until,
            target: FaultTarget::node(node),
            factor: 0.0,
        }
    }

    /// Hand-built degradation window on a named link set.
    pub fn degrade(needle: &str, factor: f64, at: SimTime, until: SimTime) -> Self {
        assert!(at < until);
        assert!(factor > 0.0 && factor < 1.0);
        InjectedFault {
            spec: format!("degrade:{needle}"),
            kind: FaultKind::Degrade { factor },
            at,
            until,
            target: FaultTarget::link(needle),
            factor,
        }
    }
}

/// Exponential draw with the given mean (inverse-CDF over the SplitMix64
/// uniform; `1 - u ∈ (0, 1]` keeps the log finite).
fn exp_sample(rng: &mut Rng, mean: f64) -> f64 {
    -(1.0 - rng.f64()).ln() * mean
}

/// Draw a deterministic fault timeline over `[0, horizon)`.
///
/// Each spec renews independently: exponential MTBF inter-arrival, then
/// an exponential MTTR repair duration; the next arrival counts from the
/// repair (a resource cannot re-fail while already failed). Each event
/// draws its target uniformly from the spec's candidates and resolves
/// its capacity factor (jitter draws per event). The result is sorted by
/// injection time and is a pure function of `(specs, horizon, seed)`.
pub fn schedule(specs: &[FaultSpec], horizon: SimTime, seed: u64) -> Vec<InjectedFault> {
    let mut out = Vec::new();
    let end = horizon.as_secs_f64();
    for (si, spec) in specs.iter().enumerate() {
        // Independent substream per spec (SplitMix64's own increment
        // constant spreads the seeds).
        let mut rng =
            Rng::seed_from_u64(seed ^ (si as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut t = 0.0f64;
        loop {
            t += exp_sample(&mut rng, spec.mtbf_s);
            if t >= end {
                break;
            }
            let dur = exp_sample(&mut rng, spec.mttr_s).max(1e-9);
            let target =
                spec.targets[rng.below(spec.targets.len() as u64) as usize].clone();
            let factor = match spec.kind {
                FaultKind::Death => 0.0,
                FaultKind::Degrade { factor } => factor,
                FaultKind::RateJitter { lo, hi } => lo + rng.f64() * (hi - lo),
            };
            out.push(InjectedFault {
                spec: spec.name.clone(),
                kind: spec.kind,
                at: SimTime::from_secs_f64(t),
                until: SimTime::from_secs_f64(t + dur),
                target,
                factor,
            });
            t += dur;
        }
    }
    out.sort_by(|a, b| a.at.cmp(&b.at).then(a.spec.cmp(&b.spec)));
    out
}

/// Lower the faults still relevant at `t0` to engine [`RateEvent`]s
/// *relative to* `t0` (a step's own virtual clock), against nominal
/// capacities read from `nominal`.
///
/// For every fault with `until > t0`: an injection event at
/// `max(at, t0) − t0` setting each matching resource to
/// `factor × nominal` (so a fault already active at `t0` lands at
/// relative time 0 — the step starts on degraded hardware), and a repair
/// event at `until − t0` restoring nominal. Faults whose needles match
/// nothing in `nominal` are skipped. Note that after a `ReLower` node
/// shrink the pool's node names are *dense relabels*, so a raw physical
/// needle like `node2.` may match the wrong survivor — callers holding a
/// shrunken pool must go through [`timeline_events_relabeled`] instead.
/// The result is time-sorted, ready for
/// [`crate::sim::run_with_events`]; events beyond the step's makespan
/// are simply never reached.
pub fn timeline_events(
    faults: &[InjectedFault],
    nominal: &ResourcePool,
    t0: SimTime,
) -> Vec<RateEvent> {
    let mut evs: Vec<RateEvent> = Vec::new();
    // Distinct needles are few (one per fault target kind) while the pool
    // is O(cluster); memoize each needle's substring scan so resolution is
    // O(distinct needles × resources), not O(faults × resources).
    let mut resolved: std::collections::HashMap<&str, Vec<crate::sim::ResourceId>> =
        std::collections::HashMap::new();
    for f in faults {
        if f.until <= t0 {
            continue;
        }
        let mut set_fault = Vec::new();
        let mut set_repair = Vec::new();
        for needle in &f.target.needles {
            let ids = resolved
                .entry(needle.as_str())
                .or_insert_with(|| nominal.find_matching(needle));
            for &id in ids.iter() {
                let cap = nominal.capacity(id);
                set_fault.push((id, cap * f.factor));
                set_repair.push((id, cap));
            }
        }
        if set_fault.is_empty() {
            continue;
        }
        evs.push(RateEvent {
            at: f.at.saturating_sub(t0),
            set: set_fault,
        });
        if f.until < SimTime::NEVER {
            evs.push(RateEvent {
                at: f.until.saturating_sub(t0),
                set: set_repair,
            });
        }
    }
    // Stable: ties keep injection-before-repair emission order per fault.
    evs.sort_by_key(|e| e.at);
    evs
}

/// The physical→dense node relabeling a `ReLower` shrink induces.
///
/// `Cluster::build` always names nodes densely (`node0..nodeN-1`), so
/// shrinking an `n`-node cluster after node `k` dies renames every
/// physical survivor `p > k` to dense index `p − |dead below p|`. Fault
/// timelines are authored against *physical* indices; this map rewrites
/// their needles so each fault keeps striking the node it was injected
/// on, and faults addressed to currently-dead nodes are dropped instead
/// of aliasing onto an innocent survivor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodeRelabel {
    /// `alive[p]` — is physical node `p` currently in the cluster?
    alive: Vec<bool>,
}

impl NodeRelabel {
    /// The identity map over `n` physical nodes (nothing dead).
    pub fn identity(n: usize) -> Self {
        NodeRelabel {
            alive: vec![true; n],
        }
    }

    /// True when no node is retired (needles pass through verbatim).
    pub fn is_identity(&self) -> bool {
        self.alive.iter().all(|a| *a)
    }

    /// Retire physical node `p` (a `ReLower` shrink). No-op when already
    /// retired or out of range.
    pub fn retire(&mut self, p: usize) {
        if let Some(a) = self.alive.get_mut(p) {
            *a = false;
        }
    }

    /// Revive physical node `p` (elastic regrow after its repair).
    pub fn revive(&mut self, p: usize) {
        if let Some(a) = self.alive.get_mut(p) {
            *a = true;
        }
    }

    /// Number of alive nodes — the shrunken cluster's node count.
    pub fn n_alive(&self) -> usize {
        self.alive.iter().filter(|a| **a).count()
    }

    /// Dense index of physical node `p` in the shrunken cluster, `None`
    /// when `p` is retired or out of range.
    pub fn dense_of(&self, p: usize) -> Option<usize> {
        if !self.alive.get(p).copied().unwrap_or(false) {
            return None;
        }
        Some(self.alive[..p].iter().filter(|a| **a).count())
    }

    /// Rewrite a pool-name needle from physical to dense node indices.
    /// Needles of the form `node<digits>…` are remapped (`None` when the
    /// addressed node is retired — the fault has no one to strike);
    /// non-node needles pass through unchanged, as do node indices beyond
    /// the map (they never matched this cluster anyway).
    pub fn rewrite_needle(&self, needle: &str) -> Option<String> {
        let Some(rest) = needle.strip_prefix("node") else {
            return Some(needle.to_string());
        };
        let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
        if digits.is_empty() {
            return Some(needle.to_string());
        }
        let p: usize = match digits.parse() {
            Ok(p) => p,
            Err(_) => return Some(needle.to_string()),
        };
        if p >= self.alive.len() {
            return Some(needle.to_string());
        }
        let dense = self.dense_of(p)?;
        Some(format!("node{dense}{}", &rest[digits.len()..]))
    }
}

/// [`timeline_events`] through a physical→dense [`NodeRelabel`]: each
/// fault's needles are rewritten before resolution, and a fault whose
/// needles all address retired nodes is dropped (it can no longer strike
/// anything — the aliasing bugfix). With the identity map this is
/// byte-for-byte `timeline_events`, preserving the zero-fault /
/// no-shrink bit-identity anchors.
pub fn timeline_events_relabeled(
    faults: &[InjectedFault],
    nominal: &ResourcePool,
    t0: SimTime,
    relabel: &NodeRelabel,
) -> Vec<RateEvent> {
    if relabel.is_identity() {
        return timeline_events(faults, nominal, t0);
    }
    let rewritten: Vec<InjectedFault> = faults
        .iter()
        .filter_map(|f| {
            let needles: Vec<String> = f
                .target
                .needles
                .iter()
                .filter_map(|n| relabel.rewrite_needle(n))
                .collect();
            if needles.is_empty() {
                return None;
            }
            let mut g = f.clone();
            g.target.needles = needles;
            Some(g)
        })
        .collect();
    timeline_events(&rewritten, nominal, t0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_nic_pool() -> ResourcePool {
        let mut p = ResourcePool::new();
        p.add("node0.nic.up.gpu0", 100.0);
        p.add("node0.nic.down.gpu0", 100.0);
        p.add("node0.nic.up.gpu1", 100.0);
        p.add("node0.nic.down.gpu1", 100.0);
        p.add("node0.nvlink.up.gpu0", 400.0);
        p
    }

    #[test]
    fn schedule_is_deterministic_per_seed() {
        let specs = vec![
            FaultSpec::any_nic_death(2, 8, 5.0, 2.0),
            FaultSpec::link_jitter("nvlink", 0.6, 0.95, 3.0, 1.0),
        ];
        let h = SimTime::from_secs_f64(100.0);
        let a = schedule(&specs, h, 42);
        let b = schedule(&specs, h, 42);
        assert_eq!(a.len(), b.len());
        assert!(!a.is_empty(), "100s horizon at 5s/3s MTBF draws events");
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.until, y.until);
            assert_eq!(x.target, y.target);
            assert_eq!(x.factor, y.factor);
        }
        let c = schedule(&specs, h, 43);
        assert!(
            a.len() != c.len() || a.iter().zip(&c).any(|(x, y)| x.at != y.at),
            "different seeds draw different timelines"
        );
        // Sorted by injection time; repairs after injections; jitter
        // factors inside the configured band.
        for w in a.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        for f in &a {
            assert!(f.at < f.until);
            if f.kind != FaultKind::Death {
                assert!((0.6..0.95).contains(&f.factor));
            }
        }
    }

    #[test]
    fn schedule_mean_interarrival_tracks_mtbf() {
        let specs = vec![FaultSpec::any_nic_death(1, 1, 4.0, 0.5)];
        let h = SimTime::from_secs_f64(20_000.0);
        let tl = schedule(&specs, h, 7);
        // Renewal rate = 1/(MTBF + MTTR) = 1/4.5 per second.
        let expect = 20_000.0 / 4.5;
        let n = tl.len() as f64;
        assert!(
            (n - expect).abs() < expect * 0.1,
            "drew {n} events, expected ≈{expect}"
        );
    }

    #[test]
    fn timeline_events_resolve_against_nominal() {
        let pool = two_nic_pool();
        let f = InjectedFault::nic_death(
            0,
            1,
            SimTime::from_secs_f64(2.0),
            SimTime::from_secs_f64(5.0),
        );
        let evs = timeline_events(&[f], &pool, SimTime::ZERO);
        assert_eq!(evs.len(), 2);
        // Injection zeroes both NIC directions; repair restores nominal.
        assert_eq!(evs[0].at, SimTime::from_secs_f64(2.0));
        assert_eq!(evs[0].set.len(), 2);
        assert!(evs[0].set.iter().all(|(_, c)| *c == 0.0));
        assert_eq!(evs[1].at, SimTime::from_secs_f64(5.0));
        assert!(evs[1].set.iter().all(|(_, c)| *c == 100.0));
        let hit: Vec<_> = evs[0].set.iter().map(|(id, _)| pool.get(*id).name.clone()).collect();
        assert!(hit.contains(&"node0.nic.up.gpu1".to_string()));
        assert!(hit.contains(&"node0.nic.down.gpu1".to_string()));
    }

    #[test]
    fn timeline_events_rebase_and_clip_to_window() {
        let pool = two_nic_pool();
        let active = InjectedFault::degrade(
            "node0.nvlink",
            0.5,
            SimTime::from_secs_f64(1.0),
            SimTime::from_secs_f64(8.0),
        );
        let past = InjectedFault::nic_death(
            0,
            0,
            SimTime::from_secs_f64(0.5),
            SimTime::from_secs_f64(2.0),
        );
        let t0 = SimTime::from_secs_f64(4.0);
        let evs = timeline_events(&[past, active], &pool, t0);
        // The repaired fault is dropped; the active one lands at rel 0
        // with its repair rebased to 4s.
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].at, SimTime::ZERO);
        assert!((evs[0].set[0].1 - 200.0).abs() < 1e-9);
        assert_eq!(evs[1].at, SimTime::from_secs_f64(4.0));
        assert!((evs[1].set[0].1 - 400.0).abs() < 1e-9);
    }

    #[test]
    fn unmatched_needles_are_skipped() {
        let pool = two_nic_pool();
        let ghost = InjectedFault::node_death(
            7,
            SimTime::from_secs_f64(1.0),
            SimTime::from_secs_f64(2.0),
        );
        assert!(timeline_events(&[ghost], &pool, SimTime::ZERO).is_empty());
    }

    #[test]
    fn relabel_maps_physical_to_dense() {
        let mut r = NodeRelabel::identity(4);
        assert!(r.is_identity());
        assert_eq!(r.n_alive(), 4);
        assert_eq!(r.dense_of(2), Some(2));
        r.retire(1);
        assert!(!r.is_identity());
        assert_eq!(r.n_alive(), 3);
        assert_eq!(r.dense_of(0), Some(0));
        assert_eq!(r.dense_of(1), None, "retired node has no dense index");
        assert_eq!(r.dense_of(2), Some(1), "survivors shift down");
        assert_eq!(r.dense_of(3), Some(2));
        // Needle rewriting follows the map; non-node needles pass through.
        assert_eq!(r.rewrite_needle("node2.nvlink"), Some("node1.nvlink".into()));
        assert_eq!(r.rewrite_needle("node3.nic.up.gpu5"), Some("node2.nic.up.gpu5".into()));
        assert_eq!(r.rewrite_needle("node1."), None, "dead node's needle retires");
        assert_eq!(r.rewrite_needle("spine.route0"), Some("spine.route0".into()));
        assert_eq!(r.rewrite_needle("node9.x"), Some("node9.x".into()));
        // Revival restores the identity mapping.
        r.revive(1);
        assert!(r.is_identity());
        assert_eq!(r.rewrite_needle("node2.nvlink"), Some("node2.nvlink".into()));
    }

    #[test]
    fn relabeled_events_keep_faults_on_physical_nodes() {
        // Pool named as a 2-node dense cluster (physical nodes 0 and 2
        // after physical node 1 died and a shrink relabeled).
        let mut pool = ResourcePool::new();
        pool.add("node0.nvlink.up.gpu0", 400.0);
        pool.add("node1.nvlink.up.gpu0", 400.0);
        let mut relabel = NodeRelabel::identity(3);
        relabel.retire(1);
        let t = |s: f64| SimTime::from_secs_f64(s);
        // A fault addressed to dead physical node 1 must be dropped, not
        // alias onto the survivor now named "node1".
        let dead = InjectedFault::node_death(1, t(1.0), t(2.0));
        let evs = timeline_events_relabeled(&[dead], &pool, SimTime::ZERO, &relabel);
        assert!(evs.is_empty(), "fault on the dead node aliased a survivor");
        // A fault on physical node 2 strikes dense "node1".
        let live = InjectedFault::degrade("node2.nvlink", 0.5, t(1.0), t(2.0));
        let evs = timeline_events_relabeled(&[live], &pool, SimTime::ZERO, &relabel);
        assert_eq!(evs.len(), 2);
        let hit = pool.get(evs[0].set[0].0).name.clone();
        assert_eq!(hit, "node1.nvlink.up.gpu0");
        // Identity map delegates bit-identically.
        let id = NodeRelabel::identity(3);
        let live2 = InjectedFault::degrade("node1.nvlink", 0.5, t(1.0), t(2.0));
        let a = timeline_events(&[live2.clone()], &pool, SimTime::ZERO);
        let b = timeline_events_relabeled(&[live2], &pool, SimTime::ZERO, &id);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.set, y.set);
        }
    }
}
