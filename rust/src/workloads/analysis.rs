//! §2.2 motivation arithmetic: "our empirical analysis of a 32B model on
//! a standard 8-H800 setup shows that for a 64K sequence length,
//! communication during the prefill stage accounts for a significant 36%
//! of the total execution time."
//!
//! We reproduce that number analytically over the calibrated substrate: a
//! 32B dense decoder under TP=8 runs, per layer, two AllReduce ops of
//! `seq × hidden` activations (attention out-proj + MLP down-proj), while
//! compute is `2 · P · seq / TP` FLOPs spread over 8 GPUs.

use crate::balancer::shares::Shares;
use crate::collectives::multipath::MultipathCollective;
use crate::collectives::CollectiveKind;
use crate::links::calib::Calibration;
use crate::topology::Topology;
use anyhow::Result;

/// Dense-decoder prefill model under tensor parallelism.
#[derive(Debug, Clone)]
pub struct PrefillSpec {
    pub params_b: f64,
    pub hidden: usize,
    pub layers: usize,
    pub seq_len: usize,
    pub tp: usize,
    /// Per-GPU sustained BF16 throughput, FLOP/s (H800 ≈ 750 TFLOPs dense,
    /// ~55% MFU in long-context prefill).
    pub flops_per_gpu: f64,
}

impl PrefillSpec {
    /// The paper's empirical setting: 32B model, 64K sequence, 8×H800.
    pub fn paper_32b_64k() -> Self {
        PrefillSpec {
            params_b: 32.0,
            hidden: 6144,
            layers: 64,
            seq_len: 64 * 1024,
            tp: 8,
            flops_per_gpu: 0.55 * 750e12,
        }
    }
}

/// The comm/compute split of one prefill.
#[derive(Debug, Clone)]
pub struct PrefillBreakdown {
    pub compute_s: f64,
    pub comm_s: f64,
    pub comm_fraction: f64,
    pub allreduce_bytes_per_layer: u64,
    pub allreduces: usize,
}

/// Time the prefill's TP AllReduce traffic on the DES (NVLink-only, NCCL
/// fashion) and compare against analytic compute time.
pub fn prefill_breakdown(topo: &Topology, spec: &PrefillSpec) -> Result<PrefillBreakdown> {
    // Two TP AllReduces per layer over seq × hidden activations,
    // reduced in fp32 (the accuracy-preserving default for TP reduce).
    let msg_bytes = (spec.seq_len * spec.hidden * 4) as u64;
    let allreduces = 2 * spec.layers;
    let mc = MultipathCollective::new(
        topo,
        Calibration::h800(),
        CollectiveKind::AllReduce,
        spec.tp,
    );
    let one = mc.run(msg_bytes, &Shares::nvlink_only())?.total().as_secs_f64();
    let comm_s = one * allreduces as f64;

    // Dense prefill compute: ≈ 2·P·tokens FLOPs (fwd), plus attention
    // O(s²·h·layers); split over tp GPUs.
    let p = spec.params_b * 1e9;
    let s = spec.seq_len as f64;
    let dense = 2.0 * p * s;
    let attn = 2.0 * 2.0 * s * s * spec.hidden as f64 * spec.layers as f64;
    let compute_s = (dense + attn) / (spec.flops_per_gpu * spec.tp as f64);

    Ok(PrefillBreakdown {
        compute_s,
        comm_s,
        comm_fraction: comm_s / (comm_s + compute_s),
        allreduce_bytes_per_layer: msg_bytes,
        allreduces,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::Preset;

    /// The §2.2 claim: comm ≈ 36% of prefill time for 32B @ 64K on 8×H800.
    #[test]
    fn paper_36pct_prefill_comm_fraction() {
        let topo = Topology::build(&Preset::H800.spec());
        let b = prefill_breakdown(&topo, &PrefillSpec::paper_32b_64k()).unwrap();
        assert!(
            (0.28..=0.44).contains(&b.comm_fraction),
            "comm fraction {:.2} outside paper's ~0.36 neighbourhood",
            b.comm_fraction
        );
    }

    #[test]
    fn comm_fraction_grows_with_sequence() {
        let topo = Topology::build(&Preset::H800.spec());
        let mut spec = PrefillSpec::paper_32b_64k();
        let f64k = prefill_breakdown(&topo, &spec).unwrap().comm_fraction;
        spec.seq_len = 8 * 1024;
        let f8k = prefill_breakdown(&topo, &spec).unwrap().comm_fraction;
        // AllReduce volume scales with s while attention compute scales
        // with s² — comm fraction must *shrink* as sequences grow.
        assert!(
            f8k > f64k,
            "8K fraction {f8k:.2} should exceed 64K fraction {f64k:.2}"
        );
    }
}
