//! Paper workload models: the §2.2 motivation scenarios (Figures 3/4 and
//! the 36%-prefill-comm claim) expressed over the same DES substrate.

pub mod analysis;
pub mod moe;
