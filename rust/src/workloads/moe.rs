//! Figures 3 & 4: the MoE training / inference communication phases and
//! the per-link idleness they exhibit under NCCL vs FlexLink.
//!
//! Figure 3 (training): per-layer AllToAll (expert dispatch/combine) and
//! gradient AllReduce over DP — NCCL leaves PCIe/RDMA "entirely idle".
//! Figure 4 (inference): intra-node TP2 AllReduce + DP4, inter-node EP64
//! (the inter-node legs are out of scope — FlexLink targets intra-node).

use crate::balancer::shares::Shares;
use crate::collectives::multipath::MultipathCollective;
use crate::collectives::CollectiveKind;
use crate::links::calib::Calibration;
use crate::links::PathId;
use crate::topology::Topology;
use anyhow::Result;

/// One communication phase of the workflow.
#[derive(Debug, Clone)]
pub struct Phase {
    pub name: String,
    pub kind: CollectiveKind,
    pub n_gpus: usize,
    pub msg_bytes: u64,
    pub calls: usize,
}

/// An MoE workflow = an ordered list of comm phases.
#[derive(Debug, Clone)]
pub struct MoeWorkflow {
    pub name: String,
    pub phases: Vec<Phase>,
}

impl MoeWorkflow {
    /// Figure 3: MoE *training* — per-layer token dispatch/combine
    /// (AllToAll) + the DP gradient AllReduce.
    pub fn training_fig3() -> Self {
        MoeWorkflow {
            name: "moe-training (Fig. 3)".into(),
            phases: vec![
                Phase {
                    name: "expert dispatch (AllToAll)".into(),
                    kind: CollectiveKind::AllToAll,
                    n_gpus: 8,
                    msg_bytes: 64 << 20,
                    calls: 16,
                },
                Phase {
                    name: "expert combine (AllToAll)".into(),
                    kind: CollectiveKind::AllToAll,
                    n_gpus: 8,
                    msg_bytes: 64 << 20,
                    calls: 16,
                },
                Phase {
                    name: "grad AllReduce (DP)".into(),
                    kind: CollectiveKind::AllReduce,
                    n_gpus: 8,
                    msg_bytes: 256 << 20,
                    calls: 4,
                },
            ],
        }
    }

    /// Figure 4: MoE *inference* — intra-node TP2 AllReduce in attention
    /// + DP4 KV AllGather phases (EP64 is inter-node, out of scope).
    pub fn inference_fig4() -> Self {
        MoeWorkflow {
            name: "moe-inference TP2/DP4 (Fig. 4)".into(),
            phases: vec![
                Phase {
                    name: "attention AllReduce (TP2)".into(),
                    kind: CollectiveKind::AllReduce,
                    n_gpus: 2,
                    msg_bytes: 128 << 20,
                    calls: 32,
                },
                Phase {
                    name: "KV AllGather (DP4)".into(),
                    kind: CollectiveKind::AllGather,
                    n_gpus: 4,
                    msg_bytes: 64 << 20,
                    calls: 8,
                },
            ],
        }
    }
}

/// Per-phase utilization under one backend.
#[derive(Debug, Clone)]
pub struct PhaseUtilization {
    pub phase: String,
    pub seconds: f64,
    /// Fraction of message carried per path (0 ⇒ the link idles).
    pub nvlink_share: f64,
    pub pcie_share: f64,
    pub rdma_share: f64,
}

/// Run the workflow's phases under given shares (NCCL = nvlink-only;
/// FlexLink = tuned) and report the per-link picture the figures draw.
pub fn utilization(
    topo: &Topology,
    flow: &MoeWorkflow,
    shares_for: impl Fn(CollectiveKind, usize) -> Shares,
) -> Result<Vec<PhaseUtilization>> {
    let mut out = Vec::with_capacity(flow.phases.len());
    for ph in &flow.phases {
        let shares = shares_for(ph.kind, ph.n_gpus);
        let mc = MultipathCollective::new(topo, Calibration::h800(), ph.kind, ph.n_gpus);
        let rep = mc.run(ph.msg_bytes, &shares)?;
        out.push(PhaseUtilization {
            phase: ph.name.clone(),
            seconds: rep.total().as_secs_f64() * ph.calls as f64,
            nvlink_share: shares.get(PathId::Nvlink) / 100.0,
            pcie_share: shares.get(PathId::Pcie) / 100.0,
            rdma_share: shares.get(PathId::Rdma) / 100.0,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::Preset;

    /// Figure 3's point: under NCCL every phase leaves PCIe and RDMA at
    /// exactly zero utilization while NVLink carries 100%.
    #[test]
    fn nccl_leaves_aux_links_idle() {
        let topo = Topology::build(&Preset::H800.spec());
        let u = utilization(&topo, &MoeWorkflow::training_fig3(), |_, _| {
            Shares::nvlink_only()
        })
        .unwrap();
        for ph in &u {
            assert_eq!(ph.pcie_share, 0.0);
            assert_eq!(ph.rdma_share, 0.0);
            assert_eq!(ph.nvlink_share, 1.0);
        }
    }

    /// FlexLink-style shares light the idle links up and the workflow's
    /// total comm time drops.
    #[test]
    fn flexlink_lights_up_idle_links_and_wins() {
        let topo = Topology::build(&Preset::H800.spec());
        let flow = MoeWorkflow::inference_fig4();
        let nccl = utilization(&topo, &flow, |_, _| Shares::nvlink_only()).unwrap();
        let flex = utilization(&topo, &flow, |_, _| {
            Shares::from_pcts(&[
                (PathId::Nvlink, 82.0),
                (PathId::Pcie, 12.0),
                (PathId::Rdma, 6.0),
            ])
        })
        .unwrap();
        let t_nccl: f64 = nccl.iter().map(|p| p.seconds).sum();
        let t_flex: f64 = flex.iter().map(|p| p.seconds).sum();
        assert!(t_flex < t_nccl, "flexlink {t_flex:.4}s vs nccl {t_nccl:.4}s");
        assert!(flex.iter().all(|p| p.pcie_share > 0.0));
    }
}
