//! Watch both balancer stages work (Algorithm 1 + Figure 5): the initial
//! coarse tuning trajectory, then the runtime Load Balancer adapting when
//! the production message size differs from the tuned one.
//!
//! Run: `cargo run --release --example tuning_trace`

use flexlink::balancer::initial_tune;
use flexlink::bench_harness::{fig5_trace, render_fig5};
use flexlink::collectives::multipath::MultipathCollective;
use flexlink::collectives::CollectiveKind;
use flexlink::config::presets::Preset;
use flexlink::config::BalancerConfig;
use flexlink::links::calib::Calibration;
use flexlink::links::PathId;
use flexlink::topology::Topology;

fn main() -> flexlink::Result<()> {
    let topo = Topology::build(&Preset::H800.spec());
    let cfg = BalancerConfig::default();
    let mc = MultipathCollective::new(&topo, Calibration::h800(), CollectiveKind::AllGather, 8);

    println!("=== Stage 1: Algorithm 1 on AllGather x8 @ 256MB ===");
    let r = initial_tune(&mc, 256 << 20, &cfg, &[PathId::Pcie, PathId::Rdma])?;
    for it in &r.history {
        let moved = it
            .moved
            .map(|(f, t, a)| format!("{f}→{t} {a:.1}pt"))
            .unwrap_or_else(|| "stable".into());
        println!(
            "  iter {:>2}  imbalance {:>5.2}  step {:>4.1}  {:<18} [{}]",
            it.iter, it.imbalance, it.step, moved, it.shares
        );
    }
    println!(
        "  converged={} after {} iterations, simulated profiling {:.2}s (paper: ≈10s)\n  final: {}",
        r.converged,
        r.iterations,
        r.profiling_time.as_secs_f64(),
        r.shares
    );

    println!("\n=== Stage 2: runtime adjustment (tuned @256MB, serving 32MB) ===");
    let trace = fig5_trace(&topo, &cfg, CollectiveKind::AllGather, 8, 256, 32, 60)?;
    print!("{}", render_fig5(&trace));
    let adjustments = trace.iter().filter(|p| p.adjusted).count();
    let first = trace.first().unwrap();
    let last = trace.last().unwrap();
    println!(
        "\n{} adjustments; completion {:.3}ms → {:.3}ms ({:+.1}%)",
        adjustments,
        first.total_ms,
        last.total_ms,
        (last.total_ms / first.total_ms - 1.0) * 100.0
    );
    Ok(())
}
