//! Compute/comm overlap end to end: a DDP-style training step where the
//! backward pass is simulated as compute chunks on one stream while each
//! finished gradient bucket's Avg-AllReduce rides a second stream behind
//! an event — all priced together on the shared stream-ordered DES, with
//! the real bytes averaged losslessly on the functional path.
//!
//! Run: `cargo run --release --example overlap_trainer`

use flexlink::collectives::CollectiveKind;
use flexlink::comm::{CommConfig, Communicator};
use flexlink::config::presets::Preset;
use flexlink::dtype::{DeviceBuffer, RedOp};
use flexlink::sim::SimTime;

fn main() -> flexlink::Result<()> {
    let n = 8;
    let cfg = CommConfig::new(Preset::H800, n);
    let mut comm = Communicator::init(cfg)?;

    // A 64 MB gradient (16M f32 params), rank r holding the value r+1
    // everywhere so the DP average is checkable by eye: (1+…+8)/8 = 4.5.
    let elems = (64 << 20) / 4;
    let grads: Vec<Vec<f32>> = (0..n).map(|r| vec![(r + 1) as f32; elems]).collect();

    // Size the simulated backward window to the solo AllReduce time —
    // the regime where gradient traffic is fully hideable in principle.
    let comm_solo = comm.time_collective(CollectiveKind::AllReduce, (elems * 4) as u64)?.time();
    let bwd = comm_solo;
    println!(
        "solo gradient AllReduce {comm_solo}, simulated backward {bwd}; \
         overlapping with {} buckets:",
        8
    );

    let buckets = 8usize;
    let chunk = SimTime::from_secs_f64(bwd.as_secs_f64() / buckets as f64);
    let compute_stream = comm.create_stream();
    let comm_stream = comm.create_stream();
    let t0 = comm.device().now();
    let mut handles = Vec::new();
    let mut bucket_devs: Vec<Vec<DeviceBuffer>> = Vec::new();
    for b in 0..buckets {
        let lo = elems * b / buckets;
        let hi = elems * (b + 1) / buckets;
        // Backward chunk b "computes" this bucket's gradient...
        comm.compute_async(chunk, compute_stream)?;
        let ready = comm.record_event(compute_stream)?;
        // ...and its AllReduce launches the moment it lands.
        comm.stream_wait_event(comm_stream, ready)?;
        let mut dev: Vec<DeviceBuffer> = grads
            .iter()
            .map(|g| DeviceBuffer::from_f32(&g[lo..hi]))
            .collect();
        handles.push(comm.all_reduce_in_place_async(&mut dev, RedOp::Avg, comm_stream)?);
        bucket_devs.push(dev);
    }
    let overlapped = comm.synchronize()?.saturating_sub(t0);

    // Lossless: every rank's every bucket holds the exact DP mean.
    for dev in &bucket_devs {
        for rank in dev {
            assert!(rank.to_f32_vec().iter().all(|&v| v == 4.5));
        }
    }

    let mut comm_total = SimTime::ZERO;
    for h in handles {
        comm_total += comm.wait(h)?.time();
    }
    let sequential = bwd + comm_total;
    println!("  bucketed comm total  {comm_total}");
    println!("  sequential (bwd+comm) {sequential}");
    println!("  overlapped window     {overlapped}");
    println!(
        "  step-time saving      {:.1}% (overlap efficiency {:.1}%)",
        (1.0 - overlapped.as_secs_f64() / sequential.as_secs_f64()) * 100.0,
        sequential.saturating_sub(overlapped).as_secs_f64()
            / bwd.as_secs_f64().min(comm_total.as_secs_f64())
            * 100.0
    );
    Ok(())
}
