//! End-to-end data-parallel training — the full three-layer stack:
//! per-rank fwd/bwd through the AOT-lowered JAX+Pallas train step (PJRT),
//! gradients really summed by FlexLink's multi-path AllReduce, Adam via
//! the AOT artifact. Logs the loss curve plus the comm-time ledger vs the
//! NCCL baseline, and writes `train_e2e.csv`.
//!
//! Run: `make artifacts && cargo run --release --example train_e2e`
//! (defaults to the ~10M-param model, 4 simulated H800 ranks, 150 steps —
//! the 1-core-sandbox stand-in for the paper-scale 100M run; pass
//! `gpt100m` as argv[1] to drive the full-size config if you have the
//! compute — see EXPERIMENTS.md §Scale.)

use flexlink::comm::CommConfig;
use flexlink::config::presets::Preset;
use flexlink::metrics::Csv;
use flexlink::trainer::{Trainer, TrainerConfig};

fn main() -> flexlink::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "gpt10m".into());
    let steps: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(150);

    let mut cfg = TrainerConfig::tiny(CommConfig::new(Preset::H800, 4));
    cfg.model = model.clone();
    cfg.steps = steps;
    cfg.lr = 3e-3;
    match model.as_str() {
        "gpt10m" => {
            cfg.batch = 4;
            cfg.seq = 128;
            cfg.vocab = 4096;
        }
        "gpt100m" => {
            cfg.batch = 2;
            cfg.seq = 256;
            cfg.vocab = 32768;
        }
        "tiny" => {
            cfg.lr = 1e-2;
        }
        other => anyhow::bail!("unknown model '{other}' (tiny|gpt10m|gpt100m)"),
    }

    let t0 = std::time::Instant::now();
    let mut trainer = Trainer::new(cfg)?;
    println!(
        "# {} | {} params | 4×H800 (simulated) | {} steps | artifacts loaded in {:.1}s",
        model,
        trainer.n_params(),
        steps,
        t0.elapsed().as_secs_f64()
    );
    println!(
        "{:>5} {:>9} {:>12} {:>12} {:>10}",
        "step", "loss", "flex comm", "nccl comm", "algbw"
    );

    let mut csv = Csv::new(&["step", "loss", "comm_ms", "nccl_comm_ms", "algbw_gbps"]);
    let mut flex_s = 0f64;
    let mut nccl_s = 0f64;
    let mut first = None;
    let mut last = 0f32;
    for step in 0..steps {
        let r = trainer.step()?;
        first.get_or_insert(r.loss);
        last = r.loss;
        flex_s += r.comm_time.as_secs_f64();
        nccl_s += r.baseline_comm_time.as_secs_f64();
        if step < 5 || step % 10 == 0 || step == steps - 1 {
            println!(
                "{:>5} {:>9.4} {:>12} {:>12} {:>7.1}GB/s",
                r.step, r.loss, r.comm_time, r.baseline_comm_time, r.algbw_gbps
            );
        }
        csv.row(&[
            r.step.to_string(),
            format!("{:.5}", r.loss),
            format!("{:.4}", r.comm_time.as_secs_f64() * 1e3),
            format!("{:.4}", r.baseline_comm_time.as_secs_f64() * 1e3),
            format!("{:.2}", r.algbw_gbps),
        ]);
    }
    csv.write_file("train_e2e.csv")?;
    println!(
        "\n# loss {:.4} → {:.4} over {steps} steps ({:.1} min wall)",
        first.unwrap(),
        last,
        t0.elapsed().as_secs_f64() / 60.0
    );
    println!(
        "# gradient comm (simulated): FlexLink {flex_s:.3}s vs NCCL {nccl_s:.3}s → {:.1}% faster",
        (nccl_s / flex_s - 1.0) * 100.0
    );
    println!("# per-step CSV: train_e2e.csv");
    Ok(())
}
