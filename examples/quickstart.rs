//! Quickstart: initialize FlexLink, run one AllReduce and one AllGather
//! through the NCCL-compatible API, and print what the paper promises —
//! bandwidth above the NCCL baseline, with byte-identical results.
//!
//! Run: `cargo run --release --example quickstart`

use flexlink::baseline::NcclBaseline;
use flexlink::collectives::CollectiveKind;
use flexlink::comm::{CommConfig, Communicator};
use flexlink::config::presets::Preset;
use flexlink::links::calib::Calibration;

fn main() -> flexlink::Result<()> {
    // 8×H800 — the paper's evaluation platform (Table 1 row 1).
    let mut comm = Communicator::init(CommConfig::new(Preset::H800, 8))?;
    println!(
        "FlexLink up: {} ranks, one-time profiling {:.2}s (simulated)",
        comm.n_ranks(),
        comm.profiling_time.as_secs_f64()
    );

    // A 64 MB gradient AllReduce (16M f32 elements).
    let elems = (64 << 20) / 4;
    let mut bufs: Vec<Vec<f32>> = (0..8).map(|r| vec![(r + 1) as f32; elems]).collect();
    let expected: f32 = (1..=8).sum::<i32>() as f32;
    let rep = comm.all_reduce_f32(&mut bufs)?;
    assert!(bufs.iter().all(|b| b.iter().all(|&v| v == expected)));

    let nccl = NcclBaseline::new(
        comm.topology(),
        Calibration::h800(),
        CollectiveKind::AllReduce,
        8,
    )
    .algbw_gbps(rep.msg_bytes)?;
    println!(
        "allreduce 64MB : {:>6.1} GB/s (NCCL {:.1} GB/s, {:+.1}%)  shares: {}",
        rep.algbw_gbps(),
        nccl,
        (rep.algbw_gbps() / nccl - 1.0) * 100.0,
        rep.shares
    );

    // A 256 MB-per-rank AllGather — the headline +27% configuration.
    let elems = (256 << 20) / 4;
    let inputs: Vec<Vec<f32>> = (0..8).map(|r| vec![r as f32; elems]).collect();
    let mut outputs = vec![Vec::new(); 8];
    let rep = comm.all_gather_f32(&inputs, &mut outputs)?;
    assert_eq!(outputs[0].len(), 8 * elems);
    let nccl = NcclBaseline::new(
        comm.topology(),
        Calibration::h800(),
        CollectiveKind::AllGather,
        8,
    )
    .algbw_gbps(rep.msg_bytes)?;
    println!(
        "allgather 256MB: {:>6.1} GB/s (NCCL {:.1} GB/s, {:+.1}%)  shares: {}",
        rep.algbw_gbps(),
        nccl,
        (rep.algbw_gbps() / nccl - 1.0) * 100.0,
        rep.shares
    );

    let o = flexlink::bench_harness::overhead(&comm);
    println!(
        "overhead (§5.4): {} MiB pinned staging, {} host copies",
        o.pinned_bytes >> 20,
        o.host_copies
    );
    Ok(())
}
