//! Quickstart: initialize FlexLink, run typed collectives through the
//! NCCL-compatible API (out-of-place buffers, full datatype/redop
//! matrix), batch a group launch, and print what the paper promises —
//! bandwidth above the NCCL baseline, with byte-identical results.
//!
//! Run: `cargo run --release --example quickstart`

use flexlink::baseline::NcclBaseline;
use flexlink::collectives::CollectiveKind;
use flexlink::comm::api::{
    flexlink_all_gather, flexlink_all_reduce, flexlink_comm_init_all, flexlink_group_end,
    flexlink_group_start, DataType, DeviceBuffer, RedOp,
};
use flexlink::config::presets::Preset;
use flexlink::links::calib::Calibration;

fn main() -> flexlink::Result<()> {
    // 8×H800 — the paper's evaluation platform (Table 1 row 1).
    let mut comm = flexlink_comm_init_all(Preset::H800, 8)?;
    println!(
        "FlexLink up: {} ranks, one-time profiling {:.2}s (simulated)",
        comm.n_ranks(),
        comm.profiling_time.as_secs_f64()
    );

    // A 64 MB gradient AllReduce (16M f32 elements), out-of-place.
    let elems = (64 << 20) / 4;
    let sends: Vec<DeviceBuffer> = (0..8)
        .map(|r| DeviceBuffer::from_f32(&vec![(r + 1) as f32; elems]))
        .collect();
    let mut recvs: Vec<DeviceBuffer> = (0..8)
        .map(|_| DeviceBuffer::zeros(DataType::F32, elems))
        .collect();
    let expected: f32 = (1..=8).sum::<i32>() as f32;
    let rep = flexlink_all_reduce(&mut comm, &sends, &mut recvs, elems, DataType::F32, RedOp::Sum)?;
    assert!(recvs
        .iter()
        .all(|b| b.to_f32_vec().iter().all(|&v| v == expected)));

    let nccl = NcclBaseline::new(
        comm.topology(),
        Calibration::h800(),
        CollectiveKind::AllReduce,
        8,
    )
    .algbw_gbps(rep.msg_bytes)?;
    println!(
        "allreduce 64MB : {:>6.1} GB/s (NCCL {:.1} GB/s, {:+.1}%)  shares: {}",
        rep.algbw_gbps(),
        nccl,
        (rep.algbw_gbps() / nccl - 1.0) * 100.0,
        rep.shares
    );

    // A 256 MB-per-rank bf16 AllGather — the headline +27% configuration,
    // in mixed precision.
    let elems = (256 << 20) / 2;
    let inputs: Vec<DeviceBuffer> = (0..8)
        .map(|r| DeviceBuffer::from_f32_as(DataType::BF16, &vec![r as f32; elems]))
        .collect();
    let mut outputs: Vec<DeviceBuffer> = (0..8)
        .map(|_| DeviceBuffer::zeros(DataType::BF16, 0))
        .collect();
    let rep = flexlink_all_gather(&mut comm, &inputs, &mut outputs, elems, DataType::BF16)?;
    assert_eq!(outputs[0].len(), 8 * elems);
    let nccl = NcclBaseline::new(
        comm.topology(),
        Calibration::h800(),
        CollectiveKind::AllGather,
        8,
    )
    .algbw_gbps(rep.msg_bytes)?;
    println!(
        "allgather 256MB (bf16): {:>6.1} GB/s (NCCL {:.1} GB/s, {:+.1}%)  shares: {}",
        rep.algbw_gbps(),
        nccl,
        (rep.algbw_gbps() / nccl - 1.0) * 100.0,
        rep.shares
    );

    // Group semantics: batch an AllReduce + AllGather into one fused
    // launch (ncclGroupStart/ncclGroupEnd) and compare against
    // launching them sequentially.
    let elems = (16 << 20) / 4;
    flexlink_group_start(&mut comm)?;
    let mut ar: Vec<DeviceBuffer> = (0..8)
        .map(|_| DeviceBuffer::from_f32(&vec![1.0f32; elems]))
        .collect();
    comm.all_reduce_in_place(&mut ar, RedOp::Avg)?;
    let ag_in: Vec<DeviceBuffer> = (0..8)
        .map(|r| DeviceBuffer::from_f32(&vec![r as f32; elems]))
        .collect();
    let mut ag_out: Vec<DeviceBuffer> = (0..8)
        .map(|_| DeviceBuffer::zeros(DataType::F32, 0))
        .collect();
    comm.all_gather(&ag_in, &mut ag_out)?;
    let group = flexlink_group_end(&mut comm)?;
    println!(
        "group launch: fused {} vs sequential {} ({:.2}x)",
        group.fused_total,
        group.sequential_total,
        group.speedup()
    );

    let o = flexlink::bench_harness::overhead(&comm);
    println!(
        "overhead (§5.4): {} MiB pinned staging, {} host copies",
        o.pinned_bytes >> 20,
        o.host_copies
    );
    Ok(())
}
