//! nccl-tests-style bandwidth sweep (the paper's measurement methodology,
//! §5.2): algorithm bandwidth for AllReduce and AllGather across message
//! sizes and GPU counts, FlexLink vs the NCCL baseline, with the
//! PCIe-only column of Table 2.
//!
//! Run: `cargo run --release --example nccl_tests`

use flexlink::balancer::{initial_tune, Shares};
use flexlink::collectives::multipath::MultipathCollective;
use flexlink::collectives::CollectiveKind;
use flexlink::config::presets::Preset;
use flexlink::config::BalancerConfig;
use flexlink::links::calib::Calibration;
use flexlink::links::PathId;
use flexlink::topology::Topology;

fn main() -> flexlink::Result<()> {
    let topo = Topology::build(&Preset::H800.spec());
    let cfg = BalancerConfig::default();
    println!(
        "# flexlink-tests (nccl-tests style) on {} — algorithm bandwidth, GB/s",
        topo.spec.name
    );
    for op in [CollectiveKind::AllReduce, CollectiveKind::AllGather] {
        for n in [2usize, 4, 8] {
            println!("\n## {op} x{n}");
            println!(
                "{:>10} {:>10} {:>12} {:>12} {:>8}   shares",
                "size", "nccl", "flex(pcie)", "flex(p+r)", "impr"
            );
            for mib in [8u64, 16, 32, 64, 128, 256, 512] {
                let msg = mib << 20;
                let mc = MultipathCollective::new(&topo, Calibration::h800(), op, n);
                let base = mc.run(msg, &Shares::nvlink_only())?.algbw_gbps();
                let pcie = initial_tune(&mc, msg, &cfg, &[PathId::Pcie])?;
                let bw_p = mc.run(msg, &pcie.shares)?.algbw_gbps();
                let full = initial_tune(&mc, msg, &cfg, &[PathId::Pcie, PathId::Rdma])?;
                let bw_f = mc.run(msg, &full.shares)?.algbw_gbps();
                println!(
                    "{:>8}MB {:>10.1} {:>12.1} {:>12.1} {:>7.1}%   {}",
                    mib,
                    base,
                    bw_p,
                    bw_f,
                    (bw_f / base - 1.0) * 100.0,
                    full.shares
                );
            }
        }
    }
    Ok(())
}
