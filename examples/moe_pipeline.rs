//! The paper's motivation scenarios (Figures 3 & 4): MoE training and
//! MoE inference communication phases, showing NCCL's link idleness and
//! what FlexLink recovers per phase.
//!
//! Run: `cargo run --release --example moe_pipeline`

use flexlink::balancer::{initial_tune, Shares};
use flexlink::collectives::multipath::MultipathCollective;
use flexlink::config::presets::Preset;
use flexlink::config::BalancerConfig;
use flexlink::links::calib::Calibration;
use flexlink::links::PathId;
use flexlink::topology::Topology;
use flexlink::workloads::moe::{utilization, MoeWorkflow};

fn main() -> flexlink::Result<()> {
    let topo = Topology::build(&Preset::H800.spec());
    let cfg = BalancerConfig::default();

    for flow in [MoeWorkflow::training_fig3(), MoeWorkflow::inference_fig4()] {
        println!("=== {} ===", flow.name);
        let nccl = utilization(&topo, &flow, |_, _| Shares::nvlink_only())?;
        let flex = utilization(&topo, &flow, |kind, n| {
            let mc = MultipathCollective::new(&topo, Calibration::h800(), kind, n);
            initial_tune(&mc, 128 << 20, &cfg, &[PathId::Pcie, PathId::Rdma])
                .map(|t| t.shares)
                .unwrap_or_else(|_| Shares::nvlink_only())
        })?;
        let mut t_nccl = 0.0;
        let mut t_flex = 0.0;
        for (a, b) in nccl.iter().zip(&flex) {
            t_nccl += a.seconds;
            t_flex += b.seconds;
            println!(
                "  {:<30} nccl {:>8.4}s [nv 100%, pcie idle, rdma idle] | flexlink {:>8.4}s [nv {:>4.1}%, pcie {:>4.1}%, rdma {:>4.1}%]",
                a.phase,
                a.seconds,
                b.seconds,
                b.nvlink_share * 100.0,
                b.pcie_share * 100.0,
                b.rdma_share * 100.0
            );
        }
        println!(
            "  total comm: {t_nccl:.4}s → {t_flex:.4}s ({:+.1}%)\n",
            (t_flex / t_nccl - 1.0) * 100.0
        );
    }

    // §2.2 prefill motivation.
    use flexlink::workloads::analysis::{prefill_breakdown, PrefillSpec};
    let b = prefill_breakdown(&topo, &PrefillSpec::paper_32b_64k())?;
    println!("=== §2.2 motivation: 32B model, 64K prefill, 8×H800 (TP8) ===");
    println!(
        "  compute {:.2}s + comm {:.2}s → comm is {:.0}% of prefill (paper: 36%)",
        b.compute_s,
        b.comm_s,
        b.comm_fraction * 100.0
    );
    Ok(())
}
